//! Power-of-two fixed-point arithmetic (paper §III-B2) — the Rust mirror
//! of `python/compile/kernels/ref.py`. Every operation here is *bit-exact*
//! with the Pallas kernels and the jnp oracles; the golden integration
//! tests pin this.
//!
//! A quantized activation is `(i16 tensor, exponent e)` meaning
//! `x_float ≈ x_q / 2^e`. All multipliers are powers of two, so every
//! rescale is an add + arithmetic shift, and rounding is
//! "half towards +inf" (`rshift_round`) — the detail the paper credits
//! for the accelerator's accuracy edge over C++-with-PTQ.

use crate::config::{A_QMAX, A_QMIN, LUT_ENTRIES, LUT_RANGE_T};
use crate::ops::Arena;
use crate::tensor::{Tensor, TensorI16};

/// Quantized tensor: int16 payload + power-of-two exponent.
///
/// The payload is Arc-backed copy-on-write (see `tensor`), so `clone()`
/// is an O(1) handle clone — keyframe-buffer entries, submit-queue
/// inputs and chain taps all share one payload until someone mutates.
#[derive(Clone, Debug)]
pub struct QTensor {
    pub t: TensorI16,
    pub exp: i32,
}

impl QTensor {
    pub fn zeros(shape: &[usize], exp: i32) -> Self {
        QTensor { t: Tensor::zeros(shape), exp }
    }

    pub fn shape(&self) -> &[usize] {
        self.t.shape()
    }
}

/// `(v + (1 << (r-1))) >> r` for r > 0 (arithmetic shift), `v << -r`
/// for r < 0, identity for r == 0. Round half towards +inf.
/// `inline(always)`: this is the innermost step of every conv epilogue;
/// it must fold into the caller's loop in release code.
#[inline(always)]
pub fn rshift_round(v: i64, r: i32) -> i64 {
    if r > 0 {
        (v + (1i64 << (r - 1))) >> r
    } else if r < 0 {
        v << (-r)
    } else {
        v
    }
}

/// Clip to the int16 activation range.
#[inline(always)]
pub fn clip_act(v: i64) -> i16 {
    v.clamp(A_QMIN as i64, A_QMAX as i64) as i16
}

/// Float -> fixed point: `clip(floor(x * 2^exp + 0.5))`.
#[inline]
pub fn quantize_f32(x: f32, exp: i32) -> i16 {
    let scaled = (x as f64 * (2.0f64).powi(exp) + 0.5).floor();
    scaled.clamp(A_QMIN as f64, A_QMAX as f64) as i16
}

#[inline]
pub fn dequantize_i16(q: i16, exp: i32) -> f32 {
    (q as f64 / (2.0f64).powi(exp)) as f32
}

/// Quantize a float slice into a caller-provided buffer (allocation-free
/// core of [`quantize_tensor`]).
#[inline]
pub fn quantize_slice(src: &[f32], exp: i32, out: &mut [i16]) {
    debug_assert_eq!(src.len(), out.len());
    for (o, &v) in out.iter_mut().zip(src) {
        *o = quantize_f32(v, exp);
    }
}

/// Dequantize an i16 slice into a caller-provided buffer (allocation-free
/// core of [`dequantize_tensor`]).
#[inline]
pub fn dequantize_slice(src: &[i16], exp: i32, out: &mut [f32]) {
    debug_assert_eq!(src.len(), out.len());
    let s = (2.0f64).powi(exp);
    for (o, &v) in out.iter_mut().zip(src) {
        *o = (v as f64 / s) as f32;
    }
}

/// Quantize a float tensor (SW requantization at extern boundaries).
pub fn quantize_tensor(x: &Tensor<f32>, exp: i32) -> QTensor {
    let mut data = vec![0i16; x.len()];
    quantize_slice(x.data(), exp, &mut data);
    QTensor { t: Tensor::from_vec(x.shape(), data), exp }
}

/// Dequantize to float (SW side of an extern transfer).
pub fn dequantize_tensor(x: &QTensor) -> Tensor<f32> {
    let mut data = vec![0f32; x.t.len()];
    dequantize_slice(x.t.data(), x.exp, &mut data);
    Tensor::from_vec(x.t.shape(), data)
}

/// Shift a payload between exponents: `r = in_exp - out_exp`. The r == 0
/// case is a plain copy. Shared core of every requant entry point.
#[inline]
fn requant_slice(src: &[i16], r: i32, out: &mut [i16]) {
    debug_assert_eq!(src.len(), out.len());
    if r == 0 {
        out.copy_from_slice(src);
        return;
    }
    for (o, &v) in out.iter_mut().zip(src) {
        *o = clip_act(rshift_round(v as i64, r));
    }
}

/// Requantize int16 -> int16 at a new exponent (the HW 'shift' stage).
/// Allocating by-ref form; prefer [`requant_owned`] (which forwards the
/// payload untouched when `x.exp == out_exp`) or [`requant_arena`] on
/// per-frame paths. The no-op case returns an O(1) handle clone (CoW
/// payload — no bytes move).
pub fn requant(x: &QTensor, out_exp: i32) -> QTensor {
    if x.exp == out_exp {
        return x.clone();
    }
    let mut data = vec![0i16; x.t.len()];
    requant_slice(x.t.data(), x.exp - out_exp, &mut data);
    QTensor { t: Tensor::from_vec(x.t.shape(), data), exp: out_exp }
}

/// Requant into a caller-provided buffer (no allocation, no-op-safe).
pub fn requant_into(x: &QTensor, out_exp: i32, out: &mut [i16]) {
    requant_slice(x.t.data(), x.exp - out_exp, out);
}

/// Requant drawing the output payload from the arena freelist.
pub fn requant_arena(x: &QTensor, out_exp: i32, arena: &mut Arena) -> QTensor {
    let mut data = arena.take_i16(x.t.len());
    requant_slice(x.t.data(), x.exp - out_exp, &mut data);
    QTensor { t: Tensor::from_vec(x.shape(), data), exp: out_exp }
}

/// Requant that consumes its input: the `x.exp == out_exp` no-op case
/// returns the payload unchanged (no deep copy — the fix for the old
/// `requant(..) -> x.clone()` path), and otherwise the spent input is
/// recycled into the arena.
pub fn requant_owned(x: QTensor, out_exp: i32, arena: &mut Arena) -> QTensor {
    if x.exp == out_exp {
        return x;
    }
    let y = requant_arena(&x, out_exp, arena);
    arena.recycle_q(x);
    y
}

/// Elementwise-add core. The lshifts into the common exponent happen in
/// **i64**: `(x as i32) << la` overflowed i32 for exponent gaps >= 17
/// (and panicked in debug for gaps >= 32) — the latent bug fixed in PR 3
/// and pinned by `add_q_survives_extreme_exponent_spreads`. The i64 form
/// is exact for gaps < 48 (`|x| <= 2^15`, so `x << 47` still fits i64);
/// real calibrated exponents are single digits, and the bound is
/// debug-asserted rather than silently wrapped.
#[inline]
fn add_q_slices(
    a: &[i16],
    b: &[i16],
    la: i32,
    lb: i32,
    r: i32,
    out: &mut [i16],
) {
    debug_assert_eq!(a.len(), out.len());
    debug_assert_eq!(b.len(), out.len());
    debug_assert!(
        la < 48 && lb < 48,
        "add_q exponent gap {la}/{lb} exceeds the exact i64 range"
    );
    for (o, (&x, &y)) in out.iter_mut().zip(a.iter().zip(b)) {
        let wide = ((x as i64) << la) + ((y as i64) << lb);
        *o = clip_act(rshift_round(wide, r));
    }
}

/// Quantized elementwise add: lshift into the max exponent (one lshift —
/// the power-of-two property), add in i64, rshift-round-clip.
pub fn add_q(a: &QTensor, b: &QTensor, out_exp: i32) -> QTensor {
    assert_eq!(a.shape(), b.shape());
    let mut data = vec![0i16; a.t.len()];
    add_q_into(a, b, out_exp, &mut data);
    QTensor { t: Tensor::from_vec(a.shape(), data), exp: out_exp }
}

/// [`add_q`] into a caller-provided buffer.
pub fn add_q_into(a: &QTensor, b: &QTensor, out_exp: i32, out: &mut [i16]) {
    assert_eq!(a.shape(), b.shape());
    let em = a.exp.max(b.exp);
    add_q_slices(
        a.t.data(),
        b.t.data(),
        em - a.exp,
        em - b.exp,
        em - out_exp,
        out,
    );
}

/// [`add_q`] drawing the output payload from the arena freelist.
pub fn add_q_arena(
    a: &QTensor,
    b: &QTensor,
    out_exp: i32,
    arena: &mut Arena,
) -> QTensor {
    assert_eq!(a.shape(), b.shape());
    let mut data = arena.take_i16(a.t.len());
    add_q_into(a, b, out_exp, &mut data);
    QTensor { t: Tensor::from_vec(a.shape(), data), exp: out_exp }
}

/// Quantized elementwise multiply: i16*i16 -> i32, rshift-round-clip.
pub fn mul_q(a: &QTensor, b: &QTensor, out_exp: i32) -> QTensor {
    assert_eq!(a.shape(), b.shape());
    let mut data = vec![0i16; a.t.len()];
    mul_q_into(a, b, out_exp, &mut data);
    QTensor { t: Tensor::from_vec(a.shape(), data), exp: out_exp }
}

/// [`mul_q`] into a caller-provided buffer.
pub fn mul_q_into(a: &QTensor, b: &QTensor, out_exp: i32, out: &mut [i16]) {
    assert_eq!(a.shape(), b.shape());
    debug_assert_eq!(a.t.len(), out.len());
    let r = a.exp + b.exp - out_exp;
    for (o, (&x, &y)) in out.iter_mut().zip(a.t.data().iter().zip(b.t.data())) {
        *o = clip_act(rshift_round(x as i64 * y as i64, r));
    }
}

/// [`mul_q`] drawing the output payload from the arena freelist.
pub fn mul_q_arena(
    a: &QTensor,
    b: &QTensor,
    out_exp: i32,
    arena: &mut Arena,
) -> QTensor {
    assert_eq!(a.shape(), b.shape());
    let mut data = arena.take_i16(a.t.len());
    mul_q_into(a, b, out_exp, &mut data);
    QTensor { t: Tensor::from_vec(a.shape(), data), exp: out_exp }
}

/// Concat shape check + per-part requant straight into the output
/// payload: no per-part intermediates, no no-op deep copies (the old
/// path cloned every part whose exponent already matched).
fn concat_q_impl(parts: &[&QTensor], out_exp: i32, data: &mut [i16]) -> Vec<usize> {
    assert!(!parts.is_empty());
    let (_, _, h, w) = parts[0].t.nchw();
    let mut off = 0;
    for p in parts {
        let (_, _, ph, pw) = p.t.nchw();
        assert_eq!((ph, pw), (h, w), "spatial mismatch in concat");
        let n = p.t.len();
        requant_slice(p.t.data(), p.exp - out_exp, &mut data[off..off + n]);
        off += n;
    }
    debug_assert_eq!(off, data.len());
    let c_total: usize = parts.iter().map(|p| p.t.nchw().1).sum();
    vec![1, c_total, h, w]
}

/// Concat along channels after requantizing every part to `out_exp`.
/// The per-part requants write directly into the output buffer.
pub fn concat_q(parts: &[&QTensor], out_exp: i32) -> QTensor {
    let total: usize = parts.iter().map(|p| p.t.len()).sum();
    let mut data = vec![0i16; total];
    let shape = concat_q_impl(parts, out_exp, &mut data);
    QTensor { t: Tensor::from_vec(&shape, data), exp: out_exp }
}

/// [`concat_q`] drawing the output payload from the arena freelist.
pub fn concat_q_arena(
    parts: &[&QTensor],
    out_exp: i32,
    arena: &mut Arena,
) -> QTensor {
    let total: usize = parts.iter().map(|p| p.t.len()).sum();
    let mut data = arena.take_i16(total);
    let shape = concat_q_impl(parts, out_exp, &mut data);
    QTensor { t: Tensor::from_vec(&shape, data), exp: out_exp }
}

// ---------------------------------------------------------------------------
// LUT activations (paper §III-B3)
// ---------------------------------------------------------------------------

/// 256-entry activation table over [-t, t] with midpoint sampling.
#[derive(Clone, Debug)]
pub struct ActLut {
    pub table: Vec<i16>,
    pub out_exp: i32,
}

impl ActLut {
    /// Build from a float function (must equal the python `build_lut`).
    pub fn build(f: impl Fn(f64) -> f64, out_exp: i32) -> Self {
        let n = LUT_ENTRIES;
        let t = LUT_RANGE_T as f64;
        let table = (0..n)
            .map(|i| {
                let x = -t + (i as f64 + 0.5) * (2.0 * t / n as f64);
                let y = f(x) * (2.0f64).powi(out_exp) + 0.5;
                y.floor().clamp(A_QMIN as f64, A_QMAX as f64) as i16
            })
            .collect();
        ActLut { table, out_exp }
    }

    pub fn from_table(table: Vec<i16>, out_exp: i32) -> Self {
        assert_eq!(table.len(), LUT_ENTRIES);
        ActLut { table, out_exp }
    }

    /// Table index of an int16 activation at exponent `in_exp`:
    /// `clamp((x + t*2^e) >> (e - 4))` (t = 8, 256 entries).
    #[inline]
    pub fn index(&self, x: i16, in_exp: i32) -> usize {
        let bias = (LUT_RANGE_T as i64) * (1i64 << in_exp.max(0));
        debug_assert!(in_exp >= 0);
        let v = x as i64 + bias;
        let shift = in_exp - 4;
        let idx = if shift > 0 {
            v >> shift
        } else if shift < 0 {
            v << (-shift)
        } else {
            v
        };
        idx.clamp(0, LUT_ENTRIES as i64 - 1) as usize
    }

    /// Apply to a raw slice at exponent `in_exp`, writing into `out`
    /// (allocation-free core; also lets callers run the LUT over a
    /// channel range of a larger payload without materialising a slice
    /// tensor first).
    pub fn apply_into(&self, src: &[i16], in_exp: i32, out: &mut [i16]) {
        debug_assert_eq!(src.len(), out.len());
        for (o, &v) in out.iter_mut().zip(src) {
            *o = self.table[self.index(v, in_exp)];
        }
    }

    /// Apply to a whole tensor.
    pub fn apply(&self, x: &QTensor) -> QTensor {
        let mut data = vec![0i16; x.t.len()];
        self.apply_into(x.t.data(), x.exp, &mut data);
        QTensor { t: Tensor::from_vec(x.shape(), data), exp: self.out_exp }
    }

    /// [`ActLut::apply`] drawing the output payload from the arena
    /// freelist.
    pub fn apply_arena(&self, x: &QTensor, arena: &mut Arena) -> QTensor {
        let mut data = arena.take_i16(x.t.len());
        self.apply_into(x.t.data(), x.exp, &mut data);
        QTensor { t: Tensor::from_vec(x.shape(), data), exp: self.out_exp }
    }
}

pub fn sigmoid_f64(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

pub fn elu_f64(x: f64) -> f64 {
    if x >= 0.0 { x } else { x.exp() - 1.0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SIGMOID_OUT_EXP;
    use crate::util::Rng;

    #[test]
    fn rshift_round_matches_python_semantics() {
        // same vector as python/tests/test_kernels.py
        let v = [5i64, -5, 6, -6, 7, -7];
        let got: Vec<i64> = v.iter().map(|&x| rshift_round(x, 2)).collect();
        assert_eq!(got, [1, -1, 2, -1, 2, -2]);
        assert_eq!(rshift_round(3, -2), 12);
        assert_eq!(rshift_round(-9, 0), -9);
    }

    #[test]
    fn quantize_round_half_up() {
        assert_eq!(quantize_f32(0.5, 0), 1);
        assert_eq!(quantize_f32(-0.5, 0), 0);
        assert_eq!(quantize_f32(1.4999, 0), 1);
        assert_eq!(quantize_f32(-1.5, 0), -1);
        assert_eq!(quantize_f32(1e9, 0), A_QMAX as i16);
        assert_eq!(quantize_f32(-1e9, 0), A_QMIN as i16);
    }

    #[test]
    fn quantize_dequantize_error_bound() {
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let x = rng.range_f32(-2.0, 2.0);
            let e = 12;
            let q = quantize_f32(x, e);
            let y = dequantize_i16(q, e);
            assert!((x - y).abs() <= 1.0 / (1 << e) as f32);
        }
    }

    #[test]
    fn add_q_property_vs_float() {
        // quantized add approximates float add within one output LSB
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let ea = rng.range_i64(8, 12) as i32;
            let eb = rng.range_i64(8, 12) as i32;
            let eo = rng.range_i64(6, 10) as i32;
            let xa = rng.range_f32(-1.5, 1.5);
            let xb = rng.range_f32(-1.5, 1.5);
            let a = QTensor {
                t: Tensor::from_vec(&[1, 1, 1, 1], vec![quantize_f32(xa, ea)]),
                exp: ea,
            };
            let b = QTensor {
                t: Tensor::from_vec(&[1, 1, 1, 1], vec![quantize_f32(xb, eb)]),
                exp: eb,
            };
            let y = add_q(&a, &b, eo);
            let yf = dequantize_i16(y.t.data()[0], eo);
            let lsb = 1.0 / (1 << eo.min(ea.min(eb))) as f32;
            assert!(
                (yf - (xa + xb)).abs() <= 2.0 * lsb,
                "{xa}+{xb} -> {yf} (ea={ea} eb={eb} eo={eo})"
            );
        }
    }

    #[test]
    fn mul_q_exact_for_small_ints() {
        let a = QTensor {
            t: Tensor::from_vec(&[1, 1, 1, 2], vec![6, -10]),
            exp: 1,
        };
        let b = QTensor {
            t: Tensor::from_vec(&[1, 1, 1, 2], vec![4, 4]),
            exp: 1,
        };
        // (6/2)*(4/2)=6 ; out exp 1 -> 12 ; r = 1+1-1 = 1
        let y = mul_q(&a, &b, 1);
        assert_eq!(y.t.data(), &[12, -20]);
    }

    #[test]
    fn lut_sigmoid_matches_reference_shape() {
        let lut = ActLut::build(sigmoid_f64, SIGMOID_OUT_EXP);
        assert_eq!(lut.table.len(), LUT_ENTRIES);
        // monotone, clamped ends
        assert!(lut.table.windows(2).all(|w| w[1] >= w[0]));
        let q = QTensor {
            t: Tensor::from_vec(&[1, 1, 1, 3], vec![0, 32000, -32000]),
            exp: 10,
        };
        let y = lut.apply(&q);
        let half = (1 << (SIGMOID_OUT_EXP - 1)) as i16;
        assert!((y.t.data()[0] - half).abs() <= half / 16);
        assert_eq!(y.t.data()[1], *lut.table.last().unwrap());
        assert_eq!(y.t.data()[2], lut.table[0]);
    }

    #[test]
    fn requant_roundtrip_lossless_when_widening() {
        let q = QTensor {
            t: Tensor::from_vec(&[1, 1, 1, 3], vec![100, -7, 3]),
            exp: 8,
        };
        let up = requant(&q, 10); // lshift 2
        let back = requant(&up, 8);
        assert_eq!(back.t.data(), q.t.data());
    }

    #[test]
    fn concat_q_requantizes_parts() {
        let a = QTensor { t: Tensor::from_vec(&[1, 1, 1, 2], vec![4, 8]), exp: 2 };
        let b = QTensor { t: Tensor::from_vec(&[1, 1, 1, 2], vec![4, 8]), exp: 3 };
        let y = concat_q(&[&a, &b], 2);
        assert_eq!(y.t.data(), &[4, 8, 2, 4]);
        assert_eq!(y.shape(), &[1, 2, 1, 2]);
        // arena twin is bit-identical
        let mut arena = Arena::new();
        let ya = concat_q_arena(&[&a, &b], 2, &mut arena);
        assert_eq!(ya.t.data(), y.t.data());
        assert_eq!(ya.shape(), y.shape());
    }

    #[test]
    fn add_q_survives_extreme_exponent_spreads() {
        // regression for the latent `(x as i32) << la` overflow: with a
        // 20-bit exponent gap the old i32 lshift wrapped (x = 4000 << 20
        // > i32::MAX), and a 35-bit gap panicked in debug builds. The
        // i64 path must keep the algebra exact: here y contributes
        // nothing after the rshift, so out == requant(a).
        // (0, 20) wraps the old i32 value (4000 << 20 > i32::MAX);
        // (0, 35) additionally hit the debug shift-amount panic
        for (ea, eb) in [(20i32, 0i32), (35, 0), (0, 20), (0, 35)] {
            let a = QTensor {
                t: Tensor::from_vec(&[1, 1, 1, 2], vec![4000i16, -4000]),
                exp: ea,
            };
            let b = QTensor {
                t: Tensor::from_vec(&[1, 1, 1, 2], vec![0i16, 0]),
                exp: eb,
            };
            // out_exp == a.exp: the sum rshifts straight back down, so
            // adding zero must return a's payload exactly
            let y = add_q(&a, &b, ea);
            assert_eq!(y.t.data(), a.t.data(), "ea={ea} eb={eb}");
            // and a genuinely mixed add at a 20-bit gap stays exact:
            // 3/2^0 + 1/2^20 at out_exp 0 rounds to 3
            let big = QTensor {
                t: Tensor::from_vec(&[1, 1, 1, 1], vec![3i16]),
                exp: 0,
            };
            let tiny = QTensor {
                t: Tensor::from_vec(&[1, 1, 1, 1], vec![1i16]),
                exp: 20,
            };
            let s = add_q(&big, &tiny, 0);
            assert_eq!(s.t.data(), &[3]);
        }
    }

    #[test]
    fn into_and_arena_variants_match_the_allocating_ops() {
        let mut rng = Rng::new(17);
        let mut arena = Arena::new();
        for _ in 0..50 {
            let n = rng.range_i64(1, 40) as usize;
            let ea = rng.range_i64(2, 12) as i32;
            let eb = rng.range_i64(2, 12) as i32;
            let eo = rng.range_i64(2, 12) as i32;
            let a = QTensor {
                t: Tensor::from_vec(
                    &[1, 1, 1, n],
                    (0..n).map(|_| rng.range_i64(-30000, 30000) as i16).collect(),
                ),
                exp: ea,
            };
            let b = QTensor {
                t: Tensor::from_vec(
                    &[1, 1, 1, n],
                    (0..n).map(|_| rng.range_i64(-30000, 30000) as i16).collect(),
                ),
                exp: eb,
            };
            assert_eq!(
                add_q(&a, &b, eo).t.data(),
                add_q_arena(&a, &b, eo, &mut arena).t.data()
            );
            assert_eq!(
                mul_q(&a, &b, eo).t.data(),
                mul_q_arena(&a, &b, eo, &mut arena).t.data()
            );
            let rq = requant(&a, eo);
            assert_eq!(rq.t.data(), requant_arena(&a, eo, &mut arena).t.data());
            // `a` is spent here: hand the value through instead of cloning
            let owned = requant_owned(a, eo, &mut arena);
            assert_eq!(owned.t.data(), rq.t.data());
            assert_eq!(owned.exp, eo);
        }
        // the no-op requant_owned forwards the payload without copying
        let q = QTensor {
            t: Tensor::from_vec(&[1, 1, 1, 2], vec![5i16, -5]),
            exp: 6,
        };
        let ptr = q.t.data().as_ptr();
        let same = requant_owned(q, 6, &mut arena);
        assert_eq!(same.t.data().as_ptr(), ptr, "no-op requant must not copy");
    }
}
