//! Power-of-two fixed-point arithmetic (paper §III-B2) — the Rust mirror
//! of `python/compile/kernels/ref.py`. Every operation here is *bit-exact*
//! with the Pallas kernels and the jnp oracles; the golden integration
//! tests pin this.
//!
//! A quantized activation is `(i16 tensor, exponent e)` meaning
//! `x_float ≈ x_q / 2^e`. All multipliers are powers of two, so every
//! rescale is an add + arithmetic shift, and rounding is
//! "half towards +inf" (`rshift_round`) — the detail the paper credits
//! for the accelerator's accuracy edge over C++-with-PTQ.

use crate::config::{A_QMAX, A_QMIN, LUT_ENTRIES, LUT_RANGE_T};
use crate::tensor::{Tensor, TensorI16};

/// Quantized tensor: int16 payload + power-of-two exponent.
#[derive(Clone, Debug)]
pub struct QTensor {
    pub t: TensorI16,
    pub exp: i32,
}

impl QTensor {
    pub fn zeros(shape: &[usize], exp: i32) -> Self {
        QTensor { t: Tensor::zeros(shape), exp }
    }

    pub fn shape(&self) -> &[usize] {
        self.t.shape()
    }
}

/// `(v + (1 << (r-1))) >> r` for r > 0 (arithmetic shift), `v << -r`
/// for r < 0, identity for r == 0. Round half towards +inf.
/// `inline(always)`: this is the innermost step of every conv epilogue;
/// it must fold into the caller's loop in release code.
#[inline(always)]
pub fn rshift_round(v: i64, r: i32) -> i64 {
    if r > 0 {
        (v + (1i64 << (r - 1))) >> r
    } else if r < 0 {
        v << (-r)
    } else {
        v
    }
}

/// Clip to the int16 activation range.
#[inline(always)]
pub fn clip_act(v: i64) -> i16 {
    v.clamp(A_QMIN as i64, A_QMAX as i64) as i16
}

/// Float -> fixed point: `clip(floor(x * 2^exp + 0.5))`.
#[inline]
pub fn quantize_f32(x: f32, exp: i32) -> i16 {
    let scaled = (x as f64 * (2.0f64).powi(exp) + 0.5).floor();
    scaled.clamp(A_QMIN as f64, A_QMAX as f64) as i16
}

#[inline]
pub fn dequantize_i16(q: i16, exp: i32) -> f32 {
    (q as f64 / (2.0f64).powi(exp)) as f32
}

/// Quantize a float tensor (SW requantization at extern boundaries).
pub fn quantize_tensor(x: &Tensor<f32>, exp: i32) -> QTensor {
    let data = x.data().iter().map(|&v| quantize_f32(v, exp)).collect();
    QTensor { t: Tensor::from_vec(x.shape(), data), exp }
}

/// Dequantize to float (SW side of an extern transfer).
pub fn dequantize_tensor(x: &QTensor) -> Tensor<f32> {
    let s = (2.0f64).powi(x.exp);
    let data = x.t.data().iter().map(|&v| (v as f64 / s) as f32).collect();
    Tensor::from_vec(x.t.shape(), data)
}

/// Requantize int16 -> int16 at a new exponent (the HW 'shift' stage).
pub fn requant(x: &QTensor, out_exp: i32) -> QTensor {
    if x.exp == out_exp {
        return x.clone();
    }
    let r = x.exp - out_exp;
    let data = x
        .t
        .data()
        .iter()
        .map(|&v| clip_act(rshift_round(v as i64, r)))
        .collect();
    QTensor { t: Tensor::from_vec(x.t.shape(), data), exp: out_exp }
}

/// Quantized elementwise add: lshift into the max exponent (one lshift —
/// the power-of-two property), add in i32, rshift-round-clip.
pub fn add_q(a: &QTensor, b: &QTensor, out_exp: i32) -> QTensor {
    assert_eq!(a.shape(), b.shape());
    let em = a.exp.max(b.exp);
    let (la, lb) = (em - a.exp, em - b.exp);
    let r = em - out_exp;
    let data = a
        .t
        .data()
        .iter()
        .zip(b.t.data())
        .map(|(&x, &y)| {
            let wide = ((x as i32) << la) as i64 + ((y as i32) << lb) as i64;
            clip_act(rshift_round(wide, r))
        })
        .collect();
    QTensor { t: Tensor::from_vec(a.shape(), data), exp: out_exp }
}

/// Quantized elementwise multiply: i16*i16 -> i32, rshift-round-clip.
pub fn mul_q(a: &QTensor, b: &QTensor, out_exp: i32) -> QTensor {
    assert_eq!(a.shape(), b.shape());
    let r = a.exp + b.exp - out_exp;
    let data = a
        .t
        .data()
        .iter()
        .zip(b.t.data())
        .map(|(&x, &y)| clip_act(rshift_round(x as i64 * y as i64, r)))
        .collect();
    QTensor { t: Tensor::from_vec(a.shape(), data), exp: out_exp }
}

/// Concat along channels after requantizing every part to `out_exp`.
pub fn concat_q(parts: &[&QTensor], out_exp: i32) -> QTensor {
    let reqs: Vec<QTensor> = parts.iter().map(|p| requant(p, out_exp)).collect();
    let refs: Vec<&TensorI16> = reqs.iter().map(|q| &q.t).collect();
    QTensor { t: Tensor::concat_channels(&refs), exp: out_exp }
}

// ---------------------------------------------------------------------------
// LUT activations (paper §III-B3)
// ---------------------------------------------------------------------------

/// 256-entry activation table over [-t, t] with midpoint sampling.
#[derive(Clone, Debug)]
pub struct ActLut {
    pub table: Vec<i16>,
    pub out_exp: i32,
}

impl ActLut {
    /// Build from a float function (must equal the python `build_lut`).
    pub fn build(f: impl Fn(f64) -> f64, out_exp: i32) -> Self {
        let n = LUT_ENTRIES;
        let t = LUT_RANGE_T as f64;
        let table = (0..n)
            .map(|i| {
                let x = -t + (i as f64 + 0.5) * (2.0 * t / n as f64);
                let y = f(x) * (2.0f64).powi(out_exp) + 0.5;
                y.floor().clamp(A_QMIN as f64, A_QMAX as f64) as i16
            })
            .collect();
        ActLut { table, out_exp }
    }

    pub fn from_table(table: Vec<i16>, out_exp: i32) -> Self {
        assert_eq!(table.len(), LUT_ENTRIES);
        ActLut { table, out_exp }
    }

    /// Table index of an int16 activation at exponent `in_exp`:
    /// `clamp((x + t*2^e) >> (e - 4))` (t = 8, 256 entries).
    #[inline]
    pub fn index(&self, x: i16, in_exp: i32) -> usize {
        let bias = (LUT_RANGE_T as i64) * (1i64 << in_exp.max(0));
        debug_assert!(in_exp >= 0);
        let v = x as i64 + bias;
        let shift = in_exp - 4;
        let idx = if shift > 0 {
            v >> shift
        } else if shift < 0 {
            v << (-shift)
        } else {
            v
        };
        idx.clamp(0, LUT_ENTRIES as i64 - 1) as usize
    }

    /// Apply to a whole tensor.
    pub fn apply(&self, x: &QTensor) -> QTensor {
        let data = x
            .t
            .data()
            .iter()
            .map(|&v| self.table[self.index(v, x.exp)])
            .collect();
        QTensor { t: Tensor::from_vec(x.shape(), data), exp: self.out_exp }
    }
}

pub fn sigmoid_f64(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

pub fn elu_f64(x: f64) -> f64 {
    if x >= 0.0 { x } else { x.exp() - 1.0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SIGMOID_OUT_EXP;
    use crate::util::Rng;

    #[test]
    fn rshift_round_matches_python_semantics() {
        // same vector as python/tests/test_kernels.py
        let v = [5i64, -5, 6, -6, 7, -7];
        let got: Vec<i64> = v.iter().map(|&x| rshift_round(x, 2)).collect();
        assert_eq!(got, [1, -1, 2, -1, 2, -2]);
        assert_eq!(rshift_round(3, -2), 12);
        assert_eq!(rshift_round(-9, 0), -9);
    }

    #[test]
    fn quantize_round_half_up() {
        assert_eq!(quantize_f32(0.5, 0), 1);
        assert_eq!(quantize_f32(-0.5, 0), 0);
        assert_eq!(quantize_f32(1.4999, 0), 1);
        assert_eq!(quantize_f32(-1.5, 0), -1);
        assert_eq!(quantize_f32(1e9, 0), A_QMAX as i16);
        assert_eq!(quantize_f32(-1e9, 0), A_QMIN as i16);
    }

    #[test]
    fn quantize_dequantize_error_bound() {
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let x = rng.range_f32(-2.0, 2.0);
            let e = 12;
            let q = quantize_f32(x, e);
            let y = dequantize_i16(q, e);
            assert!((x - y).abs() <= 1.0 / (1 << e) as f32);
        }
    }

    #[test]
    fn add_q_property_vs_float() {
        // quantized add approximates float add within one output LSB
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let ea = rng.range_i64(8, 12) as i32;
            let eb = rng.range_i64(8, 12) as i32;
            let eo = rng.range_i64(6, 10) as i32;
            let xa = rng.range_f32(-1.5, 1.5);
            let xb = rng.range_f32(-1.5, 1.5);
            let a = QTensor {
                t: Tensor::from_vec(&[1, 1, 1, 1], vec![quantize_f32(xa, ea)]),
                exp: ea,
            };
            let b = QTensor {
                t: Tensor::from_vec(&[1, 1, 1, 1], vec![quantize_f32(xb, eb)]),
                exp: eb,
            };
            let y = add_q(&a, &b, eo);
            let yf = dequantize_i16(y.t.data()[0], eo);
            let lsb = 1.0 / (1 << eo.min(ea.min(eb))) as f32;
            assert!(
                (yf - (xa + xb)).abs() <= 2.0 * lsb,
                "{xa}+{xb} -> {yf} (ea={ea} eb={eb} eo={eo})"
            );
        }
    }

    #[test]
    fn mul_q_exact_for_small_ints() {
        let a = QTensor {
            t: Tensor::from_vec(&[1, 1, 1, 2], vec![6, -10]),
            exp: 1,
        };
        let b = QTensor {
            t: Tensor::from_vec(&[1, 1, 1, 2], vec![4, 4]),
            exp: 1,
        };
        // (6/2)*(4/2)=6 ; out exp 1 -> 12 ; r = 1+1-1 = 1
        let y = mul_q(&a, &b, 1);
        assert_eq!(y.t.data(), &[12, -20]);
    }

    #[test]
    fn lut_sigmoid_matches_reference_shape() {
        let lut = ActLut::build(sigmoid_f64, SIGMOID_OUT_EXP);
        assert_eq!(lut.table.len(), LUT_ENTRIES);
        // monotone, clamped ends
        assert!(lut.table.windows(2).all(|w| w[1] >= w[0]));
        let q = QTensor {
            t: Tensor::from_vec(&[1, 1, 1, 3], vec![0, 32000, -32000]),
            exp: 10,
        };
        let y = lut.apply(&q);
        let half = (1 << (SIGMOID_OUT_EXP - 1)) as i16;
        assert!((y.t.data()[0] - half).abs() <= half / 16);
        assert_eq!(y.t.data()[1], *lut.table.last().unwrap());
        assert_eq!(y.t.data()[2], lut.table[0]);
    }

    #[test]
    fn requant_roundtrip_lossless_when_widening() {
        let q = QTensor {
            t: Tensor::from_vec(&[1, 1, 1, 3], vec![100, -7, 3]),
            exp: 8,
        };
        let up = requant(&q, 10); // lshift 2
        let back = requant(&up, 8);
        assert_eq!(back.t.data(), q.t.data());
    }

    #[test]
    fn concat_q_requantizes_parts() {
        let a = QTensor { t: Tensor::from_vec(&[1, 1, 1, 2], vec![4, 8]), exp: 2 };
        let b = QTensor { t: Tensor::from_vec(&[1, 1, 1, 2], vec![4, 8]), exp: 3 };
        let y = concat_q(&[&a, &b], 2);
        assert_eq!(y.t.data(), &[4, 8, 2, 4]);
    }
}
