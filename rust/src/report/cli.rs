//! CLI dispatch for the `fadec` binary.

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::coordinator::PipelineOptions;
use crate::hwsim::TableIIModel;
use crate::util::Args;

use super::eval::{self, EvalCtx};
use super::{tables, Paths};

const USAGE: &str = "\
fadec — FADEC reproduction driver (see DESIGN.md §7)

USAGE: fadec <command> [--artifacts DIR] [options]

COMMANDS
  analyze           Table I census + HW/SW partition (+ --mults for Fig 2)
  resources         Table III resource model
  model             Table II modeled ZCU104 column
  run               one pipeline over a scene
                      --platform float|ptq|hybrid  --scene NAME  --frames N
  eval              evaluation suite:
                      --table2 [--frames N] | --fig8 [--frames N]
                      --qualitative [--out DIR] | --overhead [--frames N]
  pipeline-chart    Fig 5 chart + overlap accounting [--frames N]
  worker            IPC backend worker (spawned by the supervisor; speaks
                      the length-prefixed TLV protocol on stdin/stdout)
  help              this text
";

pub fn dispatch(args: &Args) -> Result<()> {
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "analyze" => {
            print!("{}", tables::table_i());
            println!();
            print!("{}", tables::partition());
            if args.has("mults") {
                println!();
                print!("{}", tables::fig_2());
            }
            Ok(())
        }
        "resources" => {
            print!("{}", tables::resources_report());
            Ok(())
        }
        "model" => {
            print!("{}", tables::table_ii_modeled(&TableIIModel::compute()));
            Ok(())
        }
        "run" => {
            let ctx = EvalCtx::load(Paths::from_args(args))?;
            let scene_name = args.get("scene").unwrap_or("chess-01");
            let frames = args.get_usize("frames", 8);
            let platform = args.get("platform").unwrap_or("hybrid");
            let scene = ctx.dataset.load_scene(scene_name)?;
            let run = match platform {
                "float" => eval::run_float(&ctx, &scene, frames),
                "ptq" => eval::run_ptq(&ctx, &scene, frames),
                "hybrid" => {
                    let mut coord = ctx.coordinator(PipelineOptions::default())?;
                    eval::run_hybrid(&mut coord, &scene, frames)?
                }
                other => bail!("unknown platform '{other}'"),
            };
            let mut mse_sum = 0.0;
            for (i, d) in run.depths.iter().enumerate() {
                mse_sum += crate::metrics::mse_tensor(d, &scene.depth_tensor(i));
            }
            println!(
                "{platform} on {scene_name}: {} frames, median {:.4} s/frame \
                 (std {:.4}), mean MSE {:.4}",
                run.depths.len(),
                run.timing.median(),
                run.timing.std(),
                mse_sum / run.depths.len() as f64
            );
            Ok(())
        }
        "eval" => {
            let ctx = EvalCtx::load(Paths::from_args(args))?;
            let mut did = false;
            if args.has("table2") {
                let frames = args.get_usize("frames", 8);
                let scenes: Vec<&str> =
                    crate::data::dataset::EVAL_SCENES[..4].to_vec();
                print!("{}", eval::table_ii_measured(&ctx, frames, &scenes)?);
                print!("{}", tables::table_ii_modeled(&TableIIModel::compute()));
                did = true;
            }
            if args.has("fig8") {
                print!("{}", eval::fig8(&ctx, args.get_usize("frames", 8))?);
                did = true;
            }
            if args.has("qualitative") {
                let out = PathBuf::from(args.get("out").unwrap_or("depth_maps"));
                print!("{}", eval::qualitative(&ctx, &out)?);
                did = true;
            }
            if args.has("overhead") {
                print!(
                    "{}",
                    eval::overhead_report(&ctx, args.get_usize("frames", 16))?
                );
                did = true;
            }
            if !did {
                bail!("eval needs one of --table2 --fig8 --qualitative --overhead");
            }
            Ok(())
        }
        "worker" => crate::runtime::ipc::worker_main(args),
        "pipeline-chart" => {
            let ctx = EvalCtx::load(Paths::from_args(args))?;
            print!(
                "{}",
                eval::pipeline_chart(&ctx, args.get_usize("frames", 8))?
            );
            Ok(())
        }
        "help" | _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}
