//! Evaluation drivers: the measured Table II, the extern overhead
//! (paper §IV-A), Fig 5 (pipeline chart), Figs 6/7 (qualitative depth
//! maps), Fig 8 (scene-by-scene ΔMSE).

use std::fs;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config;
use crate::coordinator::{Coordinator, PipelineOptions};
use crate::data::dataset::{Dataset, Scene, EVAL_SCENES};
use crate::data::manifest::Manifest;
use crate::kb::KeyframeBuffer;
use crate::metrics;
use crate::model::{FloatModel, FloatParams, FloatState, QuantModel, QuantParams, QuantState};
use crate::tensor::TensorF;
use crate::util::TimingStats;

use super::Paths;

/// Everything loaded once for evaluation.
pub struct EvalCtx {
    pub manifest: Manifest,
    pub fp: FloatParams,
    pub qp: Arc<QuantParams>,
    pub dataset: Dataset,
    pub paths: Paths,
}

impl EvalCtx {
    pub fn load(paths: Paths) -> Result<Self> {
        let manifest = Manifest::load(&paths.manifest())?;
        let fp = FloatParams::load(&paths.weights())?;
        let qp = Arc::new(QuantParams::load(&paths.qparams(), &manifest)?);
        qp.validate()?;
        let dataset = Dataset::open(&paths.dataset())?;
        Ok(EvalCtx { manifest, fp, qp, dataset, paths })
    }

    pub fn coordinator(&self, opts: PipelineOptions) -> Result<Coordinator> {
        Coordinator::new(&self.paths.artifacts, &self.manifest,
                         Arc::clone(&self.qp), opts)
    }
}

/// Per-frame depths of one platform over one scene.
pub struct SceneRun {
    pub depths: Vec<TensorF>,
    pub timing: TimingStats,
}

/// CPU-only float baseline over a scene (Table II row 1).
pub fn run_float(ctx: &EvalCtx, scene: &Scene, n: usize) -> SceneRun {
    let model = FloatModel::new(&ctx.fp);
    let mut kb = KeyframeBuffer::new();
    let mut state = FloatState::zero();
    let mut out = SceneRun { depths: Vec::new(), timing: TimingStats::default() };
    for i in 0..n.min(scene.len()) {
        let img = scene.normalized_image(i);
        let t0 = Instant::now();
        let (depth, f_half) = model.step(&img, &scene.poses[i], &kb, &mut state);
        out.timing.push(t0.elapsed().as_secs_f64());
        kb.maybe_insert(scene.poses[i], f_half);
        out.depths.push(depth);
    }
    out
}

/// CPU-only PTQ baseline over a scene (Table II row 2).
pub fn run_ptq(ctx: &EvalCtx, scene: &Scene, n: usize) -> SceneRun {
    let model = QuantModel::new(Arc::clone(&ctx.qp));
    let mut kb = KeyframeBuffer::new();
    let mut state = QuantState::zero(&ctx.qp);
    let mut out = SceneRun { depths: Vec::new(), timing: TimingStats::default() };
    for i in 0..n.min(scene.len()) {
        let img = scene.normalized_image(i);
        let t0 = Instant::now();
        let (depth, f_half) = model.step(&img, &scene.poses[i], &kb, &mut state);
        out.timing.push(t0.elapsed().as_secs_f64());
        kb.maybe_insert(scene.poses[i], f_half);
        out.depths.push(depth);
    }
    out
}

/// Hybrid PL+CPU over a scene (Table II row 3).
pub fn run_hybrid(coord: &mut Coordinator, scene: &Scene, n: usize) -> Result<SceneRun> {
    coord.reset_stream();
    let mut out = SceneRun { depths: Vec::new(), timing: TimingStats::default() };
    for i in 0..n.min(scene.len()) {
        let img = scene.normalized_image(i);
        let t0 = Instant::now();
        let fo = coord.step(&img, &scene.poses[i])?;
        out.timing.push(t0.elapsed().as_secs_f64());
        out.depths.push(fo.depth);
    }
    Ok(out)
}

/// Measured Table II over the evaluation scenes.
pub fn table_ii_measured(ctx: &EvalCtx, frames_per_scene: usize,
                         scenes: &[&str]) -> Result<String> {
    let mut t_float = TimingStats::default();
    let mut t_ptq = TimingStats::default();
    let mut t_hyb = TimingStats::default();
    let mut coord = ctx.coordinator(PipelineOptions::default())?;
    for name in scenes {
        let scene = ctx.dataset.load_scene(name)?;
        let rf = run_float(ctx, &scene, frames_per_scene);
        let rq = run_ptq(ctx, &scene, frames_per_scene);
        let rh = run_hybrid(&mut coord, &scene, frames_per_scene)?;
        t_float.samples.extend(rf.timing.samples);
        t_ptq.samples.extend(rq.timing.samples);
        t_hyb.samples.extend(rh.timing.samples);
    }
    let speedup = t_float.median() / t_hyb.median();
    Ok(format!(
        "Table II — measured on this host (median / std per frame, {} scenes x {} frames)\n\
         platform            median [s]   std [s]\n\
         CPU-only            {:9.4}   {:8.4}   (paper 16.744 / 0.049)\n\
         CPU-only (w/ PTQ)   {:9.4}   {:8.4}   (paper 13.248 / 0.035)\n\
         PL + CPU (ours)     {:9.4}   {:8.4}   (paper  0.278 / 0.118)\n\
         measured speedup    {:9.1}x               (paper 60.2x)\n",
        scenes.len(), frames_per_scene,
        t_float.median(), t_float.std(),
        t_ptq.median(), t_ptq.std(),
        t_hyb.median(), t_hyb.std(),
        speedup,
    ))
}

/// Extern overhead (paper §IV-A: 4.7 ms median, 1.69% of execution time).
pub fn overhead_report(ctx: &EvalCtx, frames: usize) -> Result<String> {
    let mut coord = ctx.coordinator(PipelineOptions::default())?;
    let scene = ctx.dataset.load_scene(EVAL_SCENES[0])?;
    coord.reset_stream();
    let _ = coord.take_extern_stats();
    let mut frame_times = TimingStats::default();
    let mut per_frame_overhead = TimingStats::default();
    for i in 0..frames.min(scene.len()) {
        let img = scene.normalized_image(i);
        let t0 = Instant::now();
        coord.step(&img, &scene.poses[i])?;
        frame_times.push(t0.elapsed().as_secs_f64());
        let stats = coord.take_extern_stats();
        per_frame_overhead.push(stats.total_overhead());
    }
    let share = per_frame_overhead.median() / frame_times.median();
    Ok(format!(
        "extern overhead — (HW wait) - (SW processing) per frame\n\
         median overhead: {:.3} ms   (paper: 4.7 ms)\n\
         median frame:    {:.3} ms\n\
         share:           {:.2}%     (paper: 1.69%)\n",
        per_frame_overhead.median() * 1e3,
        frame_times.median() * 1e3,
        share * 100.0
    ))
}

/// Fig 5: pipeline chart of a representative frame + overlap accounting.
pub fn pipeline_chart(ctx: &EvalCtx, frames: usize) -> Result<String> {
    let mut coord = ctx.coordinator(PipelineOptions::default())?;
    let scene = ctx.dataset.load_scene(EVAL_SCENES[0])?;
    let mut last = None;
    let mut cvf_hidden = TimingStats::default();
    for i in 0..frames.min(scene.len()) {
        let img = scene.normalized_image(i);
        let fo = coord.step(&img, &scene.poses[i])?;
        if i >= 2 {
            // steady state: KB populated, correction active
            cvf_hidden.push(fo.profile.hidden_fraction("cvf_prep"));
        }
        last = Some(fo.profile);
    }
    let p = last.context("no frames")?;
    Ok(format!(
        "Fig 5 — pipeline chart (last frame, steady state)\n{}\n\
         CVF preparation hidden behind PL: {:.1}% median (paper: 93% of CVF hidden)\n",
        p.chart(72),
        cvf_hidden.median() * 100.0
    ))
}

/// Fig 8: per-scene MSE difference (accelerator - float reference).
pub fn fig8(ctx: &EvalCtx, frames_per_scene: usize) -> Result<String> {
    let mut coord = ctx.coordinator(PipelineOptions::default())?;
    let mut out = String::from(
        "Fig 8 — scene-by-scene MSE (float, PTQ-CPU, hybrid, Δ = hybrid - float)\n\
         scene            MSE(float)  MSE(ptq)   MSE(ours)  ΔMSE      Δ/float\n",
    );
    for name in EVAL_SCENES {
        let scene = ctx.dataset.load_scene(name)?;
        let n = frames_per_scene.min(scene.len());
        let rf = run_float(ctx, &scene, n);
        let rq = run_ptq(ctx, &scene, n);
        let rh = run_hybrid(&mut coord, &scene, n)?;
        // frame 0 is the cold-start frame (empty KB -> zero cost
        // volume); stereo from video needs a measurement frame, so the
        // accuracy average starts at frame 1 (as does DeepVideoMVS)
        let (mut mf, mut mq, mut mh) = (0.0, 0.0, 0.0);
        for i in 1..n {
            let gt = scene.depth_tensor(i);
            mf += metrics::mse_tensor(&rf.depths[i], &gt);
            mq += metrics::mse_tensor(&rq.depths[i], &gt);
            mh += metrics::mse_tensor(&rh.depths[i], &gt);
        }
        let m = (n - 1).max(1) as f64;
        let (mf, mq, mh) = (mf / m, mq / m, mh / m);
        out.push_str(&format!(
            "{name:<16} {mf:>10.4} {mq:>10.4} {mh:>10.4} {:>+9.4} {:>+8.1}%\n",
            mh - mf,
            100.0 * (mh - mf) / mf
        ));
    }
    out.push_str("paper: degradation below 10% of the float MSE in most scenes\n");
    Ok(out)
}

/// Figs 6/7: qualitative depth maps for two frames, written as PGMs.
pub fn qualitative(ctx: &EvalCtx, out_dir: &Path) -> Result<String> {
    fs::create_dir_all(out_dir)?;
    let mut coord = ctx.coordinator(PipelineOptions::default())?;
    let mut report = String::from(
        "Figs 6/7 — qualitative depth maps (PGMs under the output dir)\n\
         frame                         MSE(float)  MSE(ptq)  MSE(ours)\n",
    );
    // fire-01 frame 13 and redkitchen-07 frame 26 stand in for the
    // paper's fire-seq-01 #000139 and redkitchen-seq-07 #000268
    for (scene_name, fidx) in [("fire-01", 13usize), ("redkitchen-07", 26)] {
        let scene = ctx.dataset.load_scene(scene_name)?;
        let n = fidx + 1;
        let rf = run_float(ctx, &scene, n);
        let rq = run_ptq(ctx, &scene, n);
        let rh = run_hybrid(&mut coord, &scene, n)?;
        let gt = scene.depth_tensor(fidx);
        let tag = format!("{scene_name}_{fidx:06}");
        write_pgm(&out_dir.join(format!("{tag}_gt.pgm")), &gt)?;
        write_pgm(&out_dir.join(format!("{tag}_float.pgm")), &rf.depths[fidx])?;
        write_pgm(&out_dir.join(format!("{tag}_ptq.pgm")), &rq.depths[fidx])?;
        write_pgm(&out_dir.join(format!("{tag}_ours.pgm")), &rh.depths[fidx])?;
        report.push_str(&format!(
            "{tag:<28} {:>10.4} {:>9.4} {:>9.4}\n",
            metrics::mse_tensor(&rf.depths[fidx], &gt),
            metrics::mse_tensor(&rq.depths[fidx], &gt),
            metrics::mse_tensor(&rh.depths[fidx], &gt),
        ));
    }
    Ok(report)
}

/// Write a depth map as an 8-bit PGM (near = bright).
pub fn write_pgm(path: &Path, depth: &TensorF) -> Result<()> {
    let (_, _, h, w) = depth.nchw();
    let mut buf = format!("P5\n{w} {h}\n255\n").into_bytes();
    for &d in depth.data() {
        let t = (config::MAX_DEPTH - d.clamp(config::MIN_DEPTH, config::MAX_DEPTH))
            / (config::MAX_DEPTH - config::MIN_DEPTH);
        buf.push((t * 255.0) as u8);
    }
    fs::write(path, buf)?;
    Ok(())
}
