//! Report generation: every table and figure of the paper, printed from
//! the living system (see DESIGN.md §7 for the experiment index).

pub mod cli;
pub mod eval;
pub mod tables;

use std::path::PathBuf;

/// Locations of the build artifacts (relative to the repo root by
/// default; override with `--artifacts`).
pub struct Paths {
    pub artifacts: PathBuf,
}

impl Paths {
    pub fn from_args(args: &crate::util::Args) -> Self {
        let artifacts = PathBuf::from(
            args.get("artifacts").unwrap_or("artifacts"),
        );
        Paths { artifacts }
    }

    pub fn manifest(&self) -> PathBuf {
        self.artifacts.join("manifest.txt")
    }

    pub fn weights(&self) -> PathBuf {
        self.artifacts.join("weights.bin")
    }

    pub fn qparams(&self) -> PathBuf {
        self.artifacts.join("qparams.bin")
    }

    pub fn dataset(&self) -> PathBuf {
        self.artifacts.join("dataset")
    }

    pub fn golden(&self) -> PathBuf {
        self.artifacts.join("golden")
    }
}
