//! Table/figure printers (paper-formatted rows next to ours).

use crate::codesign;
use crate::hwsim::{ResourceModel, TableIIModel, Utilization};

/// Table I: operator census per process.
pub fn table_i() -> String {
    let got = codesign::op_census();
    let mut out = String::new();
    out.push_str(
        "Table I — operations per process (ours / paper)\n\
         operation            FE        FS        CVF       CVE       CL        CVD\n",
    );
    for (row, paper) in codesign::PAPER_TABLE_I {
        out.push_str(&format!("{row:<16}"));
        for (pi, p) in codesign::PROCESSES.iter().enumerate() {
            let g = got[p][row];
            let mark = if g == paper[pi] { ' ' } else { '!' };
            out.push_str(&format!(" {g:>4}/{:<4}{mark}", paper[pi]));
        }
        out.push('\n');
    }
    let status = match codesign::table_i_matches() {
        Ok(()) => "MATCHES the paper exactly".to_string(),
        Err(e) => format!("MISMATCH: {e}"),
    };
    out.push_str(&format!("census {status}\n"));
    out
}

/// Fig 2: multiplication share per process.
pub fn fig_2() -> String {
    let m = codesign::total_mults();
    let tot: u64 = m.values().sum();
    let mut out = String::new();
    out.push_str("Fig 2 — multiplications per process (weighted by tensor size)\n");
    for p in codesign::PROCESSES {
        let v = m[p];
        let pct = 100.0 * v as f64 / tot as f64;
        let bar = "#".repeat((pct / 2.0).round() as usize);
        out.push_str(&format!("{p:<4} {v:>12}  {pct:5.1}%  {bar}\n"));
    }
    let cve_cvd = 100.0 * (m["CVE"] + m["CVD"]) as f64 / tot as f64;
    let cvf = 100.0 * m["CVF"] as f64 / tot as f64;
    out.push_str(&format!(
        "CVE+CVD share: {cve_cvd:.1}% (paper: 82.4%)   CVF share: {cvf:.1}% (paper: 5.0%)\n"
    ));
    let cm = codesign::conv_mults();
    out.push_str(&format!(
        "conv share inside CVE+CVD: {:.1}% (paper: >99%)\n",
        100.0 * (cm["CVE"] + cm["CVD"]) as f64 / (m["CVE"] + m["CVD"]) as f64
    ));
    out
}

/// The HW/SW partition table (paper §III-A3).
pub fn partition() -> String {
    let mut out = String::new();
    out.push_str("HW/SW partitioning (derived, paper §III-A3)\n");
    out.push_str(&format!(
        "{:<16} {:<5} {:<22} rationale\n",
        "operation", "where", "access pattern"
    ));
    for d in codesign::partition() {
        out.push_str(&format!(
            "{:<16} {:<5} {:<22} {}\n",
            d.op,
            match d.assign {
                codesign::Assign::Hw => "HW",
                codesign::Assign::Sw => "SW",
            },
            d.access_pattern,
            d.rationale
        ));
    }
    out
}

/// Table III: resource utilization (modeled).
pub fn table_iii(u: &Utilization) -> String {
    let mut out = String::new();
    out.push_str(
        "Table III — ZCU104 resource model (ours vs paper's Vivado report)\n\
         name   modeled   paper    available  modeled%  paper%\n",
    );
    let paper: std::collections::BTreeMap<&str, u64> =
        crate::hwsim::resources::PAPER_TABLE_III.into_iter().collect();
    for (name, used, avail) in u.rows() {
        let p = paper[name];
        out.push_str(&format!(
            "{name:<6} {used:>8} {p:>8} {avail:>10} {:>8.1}% {:>6.1}%\n",
            100.0 * used as f64 / avail as f64,
            100.0 * p as f64 / avail as f64
        ));
    }
    out
}

/// Table II (modeled ZCU104 column).
pub fn table_ii_modeled(t: &TableIIModel) -> String {
    format!(
        "Table II — modeled ZCU104 times (paper measured in parentheses)\n\
         CPU-only          {:8.3} s   (16.744 s)\n\
         CPU-only (w/ PTQ) {:8.3} s   (13.248 s)\n\
         PL + CPU (ours)   {:8.3} s   (0.278 s)  @ {:.3} MHz\n\
         speedup           {:8.1} x   (60.2 x)\n",
        t.cpu_only_s, t.cpu_ptq_s, t.hybrid_s, t.clock_mhz, t.speedup
    )
}

/// Full resource report with the inventory.
pub fn resources_report() -> String {
    let model = ResourceModel::with_defaults();
    let (dense, dw) = model.pipeline_inventory();
    let mut out = String::new();
    out.push_str(&format!(
        "pipeline inventory: dense {:?}, depthwise {:?}\n\
         weight storage: {:.1} Kb, largest activation: {:.1} Kb\n\n",
        dense,
        dw,
        model.weight_bits() as f64 / 1024.0,
        model.max_activation_bits() as f64 / 1024.0,
    ));
    out.push_str(&table_iii(&model.estimate()));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_i_prints_and_matches() {
        let t = table_i();
        assert!(t.contains("MATCHES the paper exactly"), "{t}");
        assert!(!t.contains('!'), "mismatch marker present:\n{t}");
    }

    #[test]
    fn fig2_mentions_all_processes() {
        let f = fig_2();
        for p in codesign::PROCESSES {
            assert!(f.contains(p));
        }
    }

    #[test]
    fn table_iii_prints_five_rows() {
        let u = ResourceModel::with_defaults().estimate();
        let t = table_iii(&u);
        for name in ["Slice", "LUT", "FF", "DSP", "BRAM"] {
            assert!(t.contains(name));
        }
    }
}
