//! Fault-injecting backend wrapper — the chaos half of the PR-7
//! fault-tolerance layer.
//!
//! [`ChaosBackend`] implements [`HwBackend`] over any inner backend and
//! injects **seeded, deterministic** failures on the submit/await path:
//!
//! * **submit errors** — `submit_batch` returns `Err` before the job
//!   reaches the inner backend (the DMA-descriptor-rejected case);
//! * **wait errors** — the submission "executes" but its handle
//!   surfaces `Err` at wait (the mid-segment execution fault);
//! * **latency spikes** — the submission is delayed before delegating
//!   (a stalled command queue, no error);
//! * **stalls** — the submission *never completes*: the handle's
//!   completion channel is parked alive forever, so an untimed wait
//!   blocks indefinitely (the wedged-device case; only
//!   `SubmitHandle::wait_batch_deadline` — i.e. an enforced
//!   `RetryPolicy::round_timeout` — turns it into a retryable fault);
//! * **transient-then-heal** — after `heal_after` injected faults the
//!   backend behaves perfectly, so a bounded retry policy provably
//!   drains the schedule;
//! * **death** — [`ChaosBackend::set_dead`] makes every subsequent
//!   submission fail until revived, modelling a persistent shard loss
//!   (what the router's failover path recovers from).
//!
//! Determinism: each submission draws its fate from a PRNG seeded by
//! `options.seed` mixed with a per-backend submission counter, so a
//! given seed produces the same fault schedule on every run — and a
//! *retry* is a new submission (new counter value, new draw), so
//! transient schedules are survivable by construction. Faults never
//! mutate inputs: an injected failure drops the submitted handles
//! exactly like an abandoned round, which is why a retried submission
//! (the caller re-submits cloned handles) is bit-identical to a
//! fault-free run — pinned by `rust/tests/recovery.rs`.
//!
//! The blocking `run`/`run_batch` paths delegate untouched: chaos
//! targets the serving path (submit/await), and keeping the blocking
//! path clean lets tests compute fault-free references through the very
//! same wrapper instance.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::data::manifest::{Manifest, SegmentDesc};
use crate::poses::Mat4;
use crate::quant::QTensor;
use crate::tensor::TensorF;
use crate::util::Rng;

use super::{HwBackend, HwCompletion, SegmentId, SubmitHandle};

/// Knobs of one chaos schedule. All rates are probabilities in [0, 1]
/// drawn independently per submission, in the order submit → wait →
/// latency (at most one fault per submission; a latency spike may
/// accompany neither error).
#[derive(Clone, Copy, Debug)]
pub struct ChaosOptions {
    /// Seed of the deterministic fault schedule.
    pub seed: u64,
    /// Probability a submission errors at `submit_batch`.
    pub submit_fault_rate: f64,
    /// Probability a submission errors at `wait`.
    pub wait_fault_rate: f64,
    /// Probability a submission is delayed by `latency` first.
    pub latency_rate: f64,
    /// Duration of an injected latency spike.
    pub latency: Duration,
    /// Probability a submission stalls forever: the handle never
    /// completes, and an untimed wait on it never returns.
    pub stall_rate: f64,
    /// Stop injecting after this many faults (transient-then-heal);
    /// `None` never heals.
    pub heal_after: Option<usize>,
}

impl Default for ChaosOptions {
    fn default() -> Self {
        ChaosOptions {
            seed: 0,
            submit_fault_rate: 0.0,
            wait_fault_rate: 0.0,
            latency_rate: 0.0,
            latency: Duration::from_millis(1),
            stall_rate: 0.0,
            heal_after: None,
        }
    }
}

/// Fault-injecting [`HwBackend`] wrapper. See the module docs.
pub struct ChaosBackend {
    inner: Arc<dyn HwBackend>,
    opts: ChaosOptions,
    /// Submissions seen (the schedule index: each draw is seeded by
    /// `opts.seed` + this counter, so retries get fresh draws).
    submissions: AtomicUsize,
    /// Faults injected so far (gates `heal_after`).
    faults: AtomicUsize,
    submit_faults: AtomicUsize,
    wait_faults: AtomicUsize,
    latency_spikes: AtomicUsize,
    stalls: AtomicUsize,
    /// Senders of stalled submissions, kept alive so the matching
    /// receivers never disconnect — a stalled wait must *hang*, not
    /// fail fast (a disconnect would be indistinguishable from a
    /// crashed worker and would defeat the timeout test).
    parked: Mutex<Vec<Sender<HwCompletion>>>,
    /// Persistent-failure mode: every submission errors until revived.
    dead: AtomicBool,
}

impl ChaosBackend {
    pub fn new(inner: Arc<dyn HwBackend>, opts: ChaosOptions) -> Self {
        ChaosBackend {
            inner,
            opts,
            submissions: AtomicUsize::new(0),
            faults: AtomicUsize::new(0),
            submit_faults: AtomicUsize::new(0),
            wait_faults: AtomicUsize::new(0),
            latency_spikes: AtomicUsize::new(0),
            stalls: AtomicUsize::new(0),
            parked: Mutex::new(Vec::new()),
            dead: AtomicBool::new(false),
        }
    }

    /// The wrapped backend (tests compute fault-free references on it).
    pub fn inner(&self) -> &Arc<dyn HwBackend> {
        &self.inner
    }

    /// Kill (or revive) the backend: while dead, every submission
    /// errors regardless of the schedule — the persistent-shard-failure
    /// mode the router's failover recovers from.
    pub fn set_dead(&self, dead: bool) {
        self.dead.store(dead, Ordering::Relaxed);
    }

    pub fn is_dead(&self) -> bool {
        self.dead.load(Ordering::Relaxed)
    }

    /// Submissions that errored at submit time.
    pub fn submit_faults_injected(&self) -> usize {
        self.submit_faults.load(Ordering::Relaxed)
    }

    /// Submissions that errored at wait time.
    pub fn wait_faults_injected(&self) -> usize {
        self.wait_faults.load(Ordering::Relaxed)
    }

    /// Submissions delayed by a latency spike.
    pub fn latency_spikes_injected(&self) -> usize {
        self.latency_spikes.load(Ordering::Relaxed)
    }

    /// Submissions stalled forever (their handles never complete).
    pub fn stalls_injected(&self) -> usize {
        self.stalls.load(Ordering::Relaxed)
    }

    /// Total injected faults (submit + wait + stall; latency is not a
    /// fault).
    pub fn faults_injected(&self) -> usize {
        self.faults.load(Ordering::Relaxed)
    }

    /// Whether the schedule still injects (false once healed).
    fn armed(&self) -> bool {
        match self.opts.heal_after {
            Some(n) => self.faults.load(Ordering::Relaxed) < n,
            None => true,
        }
    }

    /// One submission's fate: (submit_fault, wait_fault, latency,
    /// stall). The stall draw comes after the original three so adding
    /// it left every pre-existing seeded schedule unchanged.
    fn draw(&self) -> (bool, bool, bool, bool) {
        let idx = self.submissions.fetch_add(1, Ordering::Relaxed) as u64;
        let mut rng = Rng::new(self.opts.seed.wrapping_add(idx.wrapping_mul(0x9E37)));
        let submit = (rng.unit_f32() as f64) < self.opts.submit_fault_rate;
        let wait = (rng.unit_f32() as f64) < self.opts.wait_fault_rate;
        let latency = (rng.unit_f32() as f64) < self.opts.latency_rate;
        let stall = (rng.unit_f32() as f64) < self.opts.stall_rate;
        (submit, wait, latency, stall)
    }
}

impl HwBackend for ChaosBackend {
    fn kind(&self) -> &'static str {
        "chaos"
    }

    fn manifest(&self) -> &Manifest {
        self.inner.manifest()
    }

    fn resolve(&self, name: &str) -> Result<SegmentId> {
        self.inner.resolve(name)
    }

    fn segment_desc(&self, id: SegmentId) -> &SegmentDesc {
        self.inner.segment_desc(id)
    }

    fn run(&self, id: SegmentId, inputs: &[&QTensor]) -> Result<Vec<QTensor>> {
        self.inner.run(id, inputs)
    }

    fn run_batch(
        &self,
        id: SegmentId,
        batch: &[Vec<&QTensor>],
    ) -> Result<Vec<Vec<QTensor>>> {
        self.inner.run_batch(id, batch)
    }

    fn submit_batch(
        &self,
        id: SegmentId,
        batch: Vec<Vec<QTensor>>,
    ) -> Result<SubmitHandle> {
        if self.dead.load(Ordering::Relaxed) {
            bail!("chaos: backend is dead (injected persistent failure)");
        }
        let (submit_fault, wait_fault, latency, stall) = self.draw();
        if latency {
            self.latency_spikes.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(self.opts.latency);
        }
        if submit_fault && self.armed() {
            self.faults.fetch_add(1, Ordering::Relaxed);
            self.submit_faults.fetch_add(1, Ordering::Relaxed);
            // the batch drops here untouched — like an abandoned round,
            // no input was mutated, so a resubmission is bit-identical
            bail!(
                "chaos: injected submit fault on segment {} \
                 (transient; retry with fresh handles)",
                self.inner.segment_desc(id).name
            );
        }
        if wait_fault && self.armed() {
            self.faults.fetch_add(1, Ordering::Relaxed);
            self.wait_faults.fetch_add(1, Ordering::Relaxed);
            let name = self.inner.segment_desc(id).name.clone();
            let now = Instant::now();
            // surfaced at wait, per the error-surfacing contract: the
            // handle is valid, its completion is the injected error
            return Ok(SubmitHandle::ready(
                Err(anyhow!(
                    "chaos: injected wait fault on segment {name} \
                     (transient; retry with fresh handles)"
                )),
                now,
                now,
            ));
        }
        if stall && self.armed() {
            self.faults.fetch_add(1, Ordering::Relaxed);
            self.stalls.fetch_add(1, Ordering::Relaxed);
            // the handle is valid but never completes: the sender is
            // parked (alive, never used), so the receiver blocks until
            // a deadline-capped wait abandons it — the batch drops
            // untouched, same replay guarantee as the other faults
            let (tx, rx) = mpsc::channel();
            self.parked.lock().expect("chaos parked poisoned").push(tx);
            return Ok(SubmitHandle::queued(rx));
        }
        self.inner.submit_batch(id, batch)
    }

    fn queue_depth(&self) -> usize {
        self.inner.queue_depth()
    }

    fn submit_payload_bytes(&self) -> u64 {
        self.inner.submit_payload_bytes()
    }

    fn set_conv_threads(&self, threads: usize) {
        self.inner.set_conv_threads(threads);
    }
}

/// Knobs of one input-side chaos schedule. All rates are probabilities
/// in [0, 1] drawn independently per `(stream, frame)` in the fixed
/// order stuck → dropout → NaN splat → bit flip → pose jump; the
/// **first applicable hit wins**, so a frame carries at most one fault
/// kind and seeded fault counts are exactly pinnable by tests.
#[derive(Clone, Copy, Debug)]
pub struct ChaosSourceOptions {
    /// Seed of the deterministic fault schedule.
    pub seed: u64,
    /// Probability the sensor repeats the previous `(frame, pose)`
    /// verbatim — a stuck capture, i.e. a zero-baseline pose pair.
    /// Inapplicable on a stream's first frame (no previous capture);
    /// the draw falls through to the next fault kind.
    pub stuck_rate: f64,
    /// Probability a contiguous pixel band saturates wildly out of
    /// range (a sensor dropout burst).
    pub dropout_rate: f64,
    /// Probability a handful of pixels become NaN (corrupted capture).
    pub nan_rate: f64,
    /// Probability one pixel gets an exponent bit flipped in transit
    /// (bit rot on the capture path).
    pub flip_rate: f64,
    /// Probability the pose translation jumps by an absurd distance
    /// (a tracking glitch).
    pub pose_jump_rate: f64,
    /// Stop injecting after this many faults (transient-then-heal);
    /// `None` never heals.
    pub heal_after: Option<usize>,
}

impl Default for ChaosSourceOptions {
    fn default() -> Self {
        ChaosSourceOptions {
            seed: 0,
            stuck_rate: 0.0,
            dropout_rate: 0.0,
            nan_rate: 0.0,
            flip_rate: 0.0,
            pose_jump_rate: 0.0,
            heal_after: None,
        }
    }
}

/// Seeded deterministic frame/pose fault injector — the input-side
/// mirror of [`ChaosBackend`]. Where `ChaosBackend` corrupts the
/// submit/await path, `ChaosSource` corrupts what the sensor hands the
/// serving loop *before* ingestion, producing exactly the fault classes
/// the guard layer (`coordinator::guard`) screens for.
///
/// Determinism: each `(stream, frame)` pair seeds its own PRNG, so the
/// schedule is independent of interleaving — a round-robin serving run
/// and a solo replay of one stream poison the very same frames. Faults
/// never mutate the caller's tensors: [`ChaosSource::corrupt`] returns
/// fresh copies, leaving clean references computable from the same
/// inputs.
pub struct ChaosSource {
    opts: ChaosSourceOptions,
    /// Faults injected so far (gates `heal_after`).
    faults: AtomicUsize,
    stuck: AtomicUsize,
    dropouts: AtomicUsize,
    nan_splats: AtomicUsize,
    bit_flips: AtomicUsize,
    pose_jumps: AtomicUsize,
}

impl ChaosSource {
    pub fn new(opts: ChaosSourceOptions) -> Self {
        ChaosSource {
            opts,
            faults: AtomicUsize::new(0),
            stuck: AtomicUsize::new(0),
            dropouts: AtomicUsize::new(0),
            nan_splats: AtomicUsize::new(0),
            bit_flips: AtomicUsize::new(0),
            pose_jumps: AtomicUsize::new(0),
        }
    }

    /// Frames replayed verbatim from the previous capture.
    pub fn stuck_injected(&self) -> usize {
        self.stuck.load(Ordering::Relaxed)
    }

    /// Frames with an out-of-range dropout band.
    pub fn dropouts_injected(&self) -> usize {
        self.dropouts.load(Ordering::Relaxed)
    }

    /// Frames with NaN-splatted pixels.
    pub fn nan_splats_injected(&self) -> usize {
        self.nan_splats.load(Ordering::Relaxed)
    }

    /// Frames with a flipped pixel bit.
    pub fn bit_flips_injected(&self) -> usize {
        self.bit_flips.load(Ordering::Relaxed)
    }

    /// Frames whose pose translation jumped.
    pub fn pose_jumps_injected(&self) -> usize {
        self.pose_jumps.load(Ordering::Relaxed)
    }

    /// Total injected faults across all kinds.
    pub fn faults_injected(&self) -> usize {
        self.faults.load(Ordering::Relaxed)
    }

    /// Whether the schedule still injects (false once healed).
    fn armed(&self) -> bool {
        match self.opts.heal_after {
            Some(n) => self.faults.load(Ordering::Relaxed) < n,
            None => true,
        }
    }

    fn note(&self, kind: &AtomicUsize) {
        self.faults.fetch_add(1, Ordering::Relaxed);
        kind.fetch_add(1, Ordering::Relaxed);
    }

    /// Possibly corrupt one capture. `stream`/`frame` identify the
    /// draw (the schedule is keyed by the pair, not by call order);
    /// `prev` is the stream's previous *corrupted* capture, needed for
    /// stuck-frame replay. Returns the capture to ingest — a fresh
    /// copy even when clean, so callers can treat it uniformly.
    pub fn corrupt(
        &self,
        stream: usize,
        frame: usize,
        img: &TensorF,
        pose: &Mat4,
        prev: Option<(&TensorF, &Mat4)>,
    ) -> (TensorF, Mat4) {
        let mut rng = Rng::new(
            self.opts
                .seed
                .wrapping_add((stream as u64).wrapping_mul(0x9E37))
                .wrapping_add((frame as u64).wrapping_mul(0x51C7)),
        );
        // all five draws happen unconditionally so the schedule for a
        // given (stream, frame) never depends on the configured rates'
        // short-circuiting — only on the seed
        let stuck = (rng.unit_f32() as f64) < self.opts.stuck_rate;
        let dropout = (rng.unit_f32() as f64) < self.opts.dropout_rate;
        let nan = (rng.unit_f32() as f64) < self.opts.nan_rate;
        let flip = (rng.unit_f32() as f64) < self.opts.flip_rate;
        let jump = (rng.unit_f32() as f64) < self.opts.pose_jump_rate;
        if self.armed() {
            if stuck {
                if let Some((pi, pp)) = prev {
                    self.note(&self.stuck);
                    return (pi.clone(), *pp);
                }
                // first frame of the stream: stuck is inapplicable,
                // fall through to the remaining kinds
            }
            if dropout {
                self.note(&self.dropouts);
                let mut out = img.clone();
                let n = out.len();
                let span = (n / 16).max(1);
                let start = rng.below((n - span + 1) as u64) as usize;
                for v in out.data_mut().iter_mut().skip(start).take(span) {
                    *v = 1.0e9;
                }
                return (out, *pose);
            }
            if nan {
                self.note(&self.nan_splats);
                let mut out = img.clone();
                let n = out.len();
                let data = out.data_mut();
                for _ in 0..4 {
                    data[rng.below(n as u64) as usize] = f32::NAN;
                }
                return (out, *pose);
            }
            if flip {
                self.note(&self.bit_flips);
                let mut out = img.clone();
                let n = out.len();
                let i = rng.below(n as u64) as usize;
                let data = out.data_mut();
                // flipping an exponent bit scales the pixel by 2^64 or
                // produces inf/NaN — either way the guard's range or
                // finiteness check catches it
                data[i] = f32::from_bits(data[i].to_bits() ^ 0x4000_0000);
                return (out, *pose);
            }
            if jump {
                self.note(&self.pose_jumps);
                let mut p = *pose;
                p.0[3] += 1.0e6;
                return (img.clone(), p);
            }
        }
        (img.clone(), *pose)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::quant::quantize_tensor;
    use crate::runtime::RefBackend;

    fn image(seed: u64) -> TensorF {
        let mut rng = Rng::new(seed);
        let n = 3 * config::IMG_H * config::IMG_W;
        TensorF::from_vec(
            &[1, 3, config::IMG_H, config::IMG_W],
            (0..n).map(|_| rng.range_f32(-2.0, 2.0)).collect(),
        )
    }

    fn chaotic(opts: ChaosOptions) -> (ChaosBackend, QTensor, SegmentId) {
        let inner = Arc::new(RefBackend::synthetic(7));
        let img = quantize_tensor(&image(1), inner.qp().aexp("image"));
        let be = ChaosBackend::new(inner, opts);
        let id = be.resolve("fe_fs").unwrap();
        (be, img, id)
    }

    #[test]
    fn clean_schedule_is_transparent_and_bit_exact() {
        let (be, img, id) = chaotic(ChaosOptions::default());
        let want = be.run(id, &[&img]).unwrap();
        let got = be.submit(id, vec![img]).unwrap().wait().unwrap();
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.t.data(), b.t.data());
            assert_eq!(a.exp, b.exp);
        }
        assert_eq!(be.faults_injected(), 0);
        assert_eq!(be.kind(), "chaos");
        assert_eq!(be.manifest().segments.len(), 19);
    }

    #[test]
    fn same_seed_same_fault_schedule() {
        let opts = ChaosOptions {
            seed: 42,
            submit_fault_rate: 0.3,
            wait_fault_rate: 0.3,
            ..Default::default()
        };
        let run = |opts: ChaosOptions| -> Vec<bool> {
            let (be, img, id) = chaotic(opts);
            (0..20)
                .map(|_| {
                    match be.submit(id, vec![img.clone()]) {
                        Err(_) => false,
                        Ok(h) => h.wait().is_ok(),
                    }
                })
                .collect()
        };
        assert_eq!(run(opts), run(opts), "seeded schedule is deterministic");
        let other = ChaosOptions { seed: 43, ..opts };
        assert_ne!(run(opts), run(other), "different seeds differ");
    }

    #[test]
    fn wait_faults_surface_at_wait_not_submit() {
        let (be, img, id) = chaotic(ChaosOptions {
            seed: 1,
            wait_fault_rate: 1.0,
            ..Default::default()
        });
        let h = be.submit(id, vec![img]).unwrap();
        let err = h.wait().unwrap_err();
        assert!(format!("{err:#}").contains("injected wait fault"));
        assert_eq!(be.wait_faults_injected(), 1);
        assert_eq!(be.submit_faults_injected(), 0);
    }

    #[test]
    fn heal_after_bounds_the_schedule() {
        let (be, img, id) = chaotic(ChaosOptions {
            seed: 5,
            submit_fault_rate: 1.0,
            heal_after: Some(3),
            ..Default::default()
        });
        let mut failures = 0;
        for _ in 0..10 {
            if be.submit(id, vec![img.clone()]).is_err() {
                failures += 1;
            }
        }
        assert_eq!(failures, 3, "exactly heal_after faults fire");
        assert_eq!(be.faults_injected(), 3);
        // healed: submissions now execute and match the blocking path
        let want = be.run(id, &[&img]).unwrap();
        let got = be.submit(id, vec![img]).unwrap().wait().unwrap();
        assert_eq!(got[0].t.data(), want[0].t.data());
    }

    #[test]
    fn dead_backend_fails_until_revived() {
        let (be, img, id) = chaotic(ChaosOptions::default());
        be.set_dead(true);
        assert!(be.is_dead());
        let err = be.submit(id, vec![img.clone()]).unwrap_err();
        assert!(format!("{err:#}").contains("dead"));
        be.set_dead(false);
        assert!(be.submit(id, vec![img]).unwrap().wait().is_ok());
    }

    #[test]
    fn stalled_submission_hangs_until_deadline_wait_abandons_it() {
        let (be, img, id) = chaotic(ChaosOptions {
            seed: 3,
            stall_rate: 1.0,
            ..Default::default()
        });
        let h = be.submit(id, vec![img.clone()]).unwrap();
        let t0 = Instant::now();
        let err = h
            .wait_batch_deadline(Duration::from_millis(20))
            .unwrap_err();
        assert!(t0.elapsed() >= Duration::from_millis(20));
        assert!(
            format!("{err:#}").contains("timed out"),
            "stall must surface as a wait timeout, got: {err:#}"
        );
        assert_eq!(be.stalls_injected(), 1);
        assert_eq!(be.faults_injected(), 1);
        // heal_after bounds stalls like any other fault: a schedule
        // healed at one stall serves the next submission normally
        let (be, img, id) = chaotic(ChaosOptions {
            seed: 3,
            stall_rate: 1.0,
            heal_after: Some(1),
            ..Default::default()
        });
        let h = be.submit(id, vec![img.clone()]).unwrap();
        assert!(h
            .wait_batch_deadline(Duration::from_millis(20))
            .is_err());
        let want = be.run(id, &[&img]).unwrap();
        let got = be.submit(id, vec![img]).unwrap().wait().unwrap();
        assert_eq!(got[0].t.data(), want[0].t.data());
        assert_eq!(be.stalls_injected(), 1);
    }

    #[test]
    fn chaos_source_same_seed_same_schedule() {
        let opts = ChaosSourceOptions {
            seed: 11,
            nan_rate: 0.2,
            pose_jump_rate: 0.2,
            dropout_rate: 0.2,
            ..Default::default()
        };
        let run = |opts: ChaosSourceOptions| -> Vec<Vec<f32>> {
            let src = ChaosSource::new(opts);
            let img = image(2);
            let pose = Mat4::identity();
            (0..12)
                .map(|f| {
                    let (i, p) = src.corrupt(0, f, &img, &pose, None);
                    let mut sig: Vec<f32> = i.data().to_vec();
                    sig.extend(p.0.iter().map(|v| *v as f32));
                    sig
                })
                .collect()
        };
        let a = run(opts);
        let b = run(opts);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            // NaN-aware bit equality
            let xb: Vec<u32> = x.iter().map(|v| v.to_bits()).collect();
            let yb: Vec<u32> = y.iter().map(|v| v.to_bits()).collect();
            assert_eq!(xb, yb, "seeded source schedule is deterministic");
        }
        // keyed by (stream, frame), not call order: stream 3 frame 7
        // draws the same fate no matter what ran before it
        let s1 = ChaosSource::new(opts);
        let s2 = ChaosSource::new(opts);
        let img = image(2);
        let pose = Mat4::identity();
        for f in 0..7 {
            s1.corrupt(3, f, &img, &pose, None);
        }
        let (i1, p1) = s1.corrupt(3, 7, &img, &pose, None);
        let (i2, p2) = s2.corrupt(3, 7, &img, &pose, None);
        let b1: Vec<u32> = i1.data().iter().map(|v| v.to_bits()).collect();
        let b2: Vec<u32> = i2.data().iter().map(|v| v.to_bits()).collect();
        assert_eq!(b1, b2);
        assert_eq!(
            p1.0.map(f64::to_bits),
            p2.0.map(f64::to_bits),
            "draws are order-independent"
        );
    }

    #[test]
    fn chaos_source_kinds_and_heal_bound() {
        // zero rates: transparent, returns verbatim copies
        let src = ChaosSource::new(ChaosSourceOptions::default());
        let img = image(4);
        let pose = Mat4::identity();
        let (i, p) = src.corrupt(0, 0, &img, &pose, None);
        assert_eq!(i.data(), img.data());
        assert_eq!(p.0, pose.0);
        assert_eq!(src.faults_injected(), 0);

        // stuck replays the previous capture verbatim, but is
        // inapplicable without one (falls through to clean here)
        let src = ChaosSource::new(ChaosSourceOptions {
            seed: 1,
            stuck_rate: 1.0,
            ..Default::default()
        });
        let (i0, _) = src.corrupt(0, 0, &img, &pose, None);
        assert_eq!(i0.data(), img.data());
        assert_eq!(src.stuck_injected(), 0);
        let prev_img = image(5);
        let mut prev_pose = Mat4::identity();
        prev_pose.0[3] = 0.5;
        let (i1, p1) = src.corrupt(0, 1, &img, &pose, Some((&prev_img, &prev_pose)));
        assert_eq!(i1.data(), prev_img.data());
        assert_eq!(p1.0, prev_pose.0);
        assert_eq!(src.stuck_injected(), 1);

        // NaN splat poisons pixels; pose jump displaces translation
        let src = ChaosSource::new(ChaosSourceOptions {
            seed: 2,
            nan_rate: 1.0,
            ..Default::default()
        });
        let (i, p) = src.corrupt(0, 0, &img, &pose, None);
        assert!(i.data().iter().any(|v| v.is_nan()));
        assert_eq!(p.0, pose.0);
        assert_eq!(src.nan_splats_injected(), 1);
        let src = ChaosSource::new(ChaosSourceOptions {
            seed: 2,
            pose_jump_rate: 1.0,
            ..Default::default()
        });
        let (i, p) = src.corrupt(0, 0, &img, &pose, None);
        assert_eq!(i.data(), img.data());
        assert!(p.0[3] > 1.0e5, "translation jumped");
        assert_eq!(src.pose_jumps_injected(), 1);

        // dropout saturates a band out of range without NaNs
        let src = ChaosSource::new(ChaosSourceOptions {
            seed: 3,
            dropout_rate: 1.0,
            ..Default::default()
        });
        let (i, _) = src.corrupt(0, 0, &img, &pose, None);
        let hot = i.data().iter().filter(|v| **v == 1.0e9).count();
        assert_eq!(hot, img.len() / 16, "contiguous dropout band");

        // first hit wins: with every rate at 1.0 exactly one kind
        // fires per frame (dropout, since stuck is inapplicable)
        let src = ChaosSource::new(ChaosSourceOptions {
            seed: 4,
            stuck_rate: 1.0,
            dropout_rate: 1.0,
            nan_rate: 1.0,
            flip_rate: 1.0,
            pose_jump_rate: 1.0,
            ..Default::default()
        });
        src.corrupt(0, 0, &img, &pose, None);
        assert_eq!(src.faults_injected(), 1);
        assert_eq!(src.dropouts_injected(), 1);
        assert_eq!(src.nan_splats_injected(), 0);

        // heal_after bounds the schedule exactly
        let src = ChaosSource::new(ChaosSourceOptions {
            seed: 5,
            nan_rate: 1.0,
            heal_after: Some(2),
            ..Default::default()
        });
        for f in 0..8 {
            src.corrupt(0, f, &img, &pose, None);
        }
        assert_eq!(src.faults_injected(), 2, "exactly heal_after faults");
        let (i, _) = src.corrupt(0, 8, &img, &pose, None);
        assert_eq!(i.data(), img.data(), "healed schedule is transparent");

        // bit flip perturbs exactly one pixel
        let src = ChaosSource::new(ChaosSourceOptions {
            seed: 6,
            flip_rate: 1.0,
            ..Default::default()
        });
        let (i, _) = src.corrupt(0, 0, &img, &pose, None);
        let diffs = i
            .data()
            .iter()
            .zip(img.data())
            .filter(|(a, b)| a.to_bits() != b.to_bits())
            .count();
        assert_eq!(diffs, 1, "one flipped pixel");
        assert_eq!(src.bit_flips_injected(), 1);
    }

    #[test]
    fn latency_spikes_delay_but_do_not_corrupt() {
        let (be, img, id) = chaotic(ChaosOptions {
            seed: 9,
            latency_rate: 1.0,
            latency: Duration::from_millis(2),
            ..Default::default()
        });
        let want = be.run(id, &[&img]).unwrap();
        let t0 = Instant::now();
        let got = be.submit(id, vec![img]).unwrap().wait().unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(2));
        assert_eq!(be.latency_spikes_injected(), 1);
        assert_eq!(got[0].t.data(), want[0].t.data());
    }
}
