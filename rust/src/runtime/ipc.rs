//! Process-isolated backend — [`IpcBackend`] serves the [`HwBackend`]
//! contract over the stdin/stdout pipes of a `fadec worker` child
//! process (see the "Process isolation & supervision" section of the
//! module docs for the full contract).
//!
//! # Wire format
//!
//! Both directions carry *frames*: `[u32 LE length][TLV body]`, where
//! the body is the hardened `data/tlv.rs` container (hostile-input
//! validated, deterministic encoding). Scalars ride as tiny tensor
//! entries — a `u64` as an i32 pair (hi, lo), strings as i8 byte
//! tensors, quantized tensors natively as i16 entries carrying their
//! exponent — so the protocol inherits the TLV loader's truncation /
//! overflow / duplicate-name rejection wholesale. A frame longer than
//! [`MAX_FRAME_BYTES`] is rejected *before* any allocation.
//!
//! Requests (parent → worker) carry an `op` entry: `hello` (handshake:
//! seed, conv threads, heartbeat period; the reply carries the worker's
//! manifest/parameter fingerprints for verification), `run_batch` (a
//! segment *name* — ids are per-process and do not survive restarts —
//! plus the input batch), `ping`, and the fault injectors `stall`
//! (serve loop parks; heartbeats continue), `freeze` (heartbeats stop
//! too — the SIGSTOP analog) and `shutdown`. Replies carry `ok`/`err`
//! plus the outputs and the worker-side execution seconds; heartbeat
//! frames (a lone `beat` counter) interleave with replies on stdout.
//!
//! The worker serves requests strictly in order on one thread, so
//! replies are FIFO; the parent's dedicated reader thread matches them
//! to a FIFO queue of pending completions — exactly the in-order
//! completion the submit/await contract requires. A reply with no
//! pending request, a corrupt frame, or EOF poisons the connection:
//! the reader marks the worker down and fails every pending wait, which
//! is what lets `coordinator::RetryPolicy` and the
//! [`Supervisor`](super::supervisor::Supervisor) turn a crashed or
//! wedged child into a retryable fault instead of UB or a deadlock.

use std::collections::{HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::path::{Path, PathBuf};
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::data::manifest::{Manifest, SegmentDesc};
use crate::data::tlv::{TlvEntry, TlvFile, TlvPayload};
use crate::metrics::SupervisorStats;
use crate::model::weights::QuantParams;
use crate::quant::QTensor;
use crate::tensor::Tensor;
use crate::util::Args;

use super::supervisor::{Supervisor, SupervisorOptions};
use super::{check_inputs, HwBackend, HwCompletion, SegmentId, SubmitHandle};

/// Upper bound on one frame's TLV body. Checked on both sides before
/// any length-driven allocation; generous next to the largest real
/// round (a full-fleet image batch is a few MiB).
pub const MAX_FRAME_BYTES: usize = 256 << 20;

/// Protocol revision carried in the handshake; bumped on any wire
/// change so a version-skewed parent/worker pair fails loudly.
pub const PROTO_VERSION: u64 = 1;

const OP_HELLO: &str = "hello";
const OP_RUN_BATCH: &str = "run_batch";
const OP_PING: &str = "ping";
const OP_CONV: &str = "conv_threads";
const OP_STALL: &str = "stall";
const OP_FREEZE: &str = "freeze";
const OP_SHUTDOWN: &str = "shutdown";

const KEY_OP: &str = "op";
const KEY_OK: &str = "ok";
const KEY_ERR: &str = "err";
const KEY_BEAT: &str = "beat";

// --- frame codec -----------------------------------------------------------

/// Write one length-prefixed frame (a single `write_all` + flush, so
/// concurrent writers interleave only at frame granularity — callers
/// serialize on a mutex anyway).
pub fn write_frame(w: &mut impl Write, tlv: &TlvFile) -> Result<()> {
    let body = tlv.to_bytes()?;
    ensure!(
        body.len() <= MAX_FRAME_BYTES,
        "IPC frame of {} bytes exceeds the {} byte bound",
        body.len(),
        MAX_FRAME_BYTES
    );
    let mut buf = Vec::with_capacity(4 + body.len());
    buf.extend_from_slice(&(body.len() as u32).to_le_bytes());
    buf.extend_from_slice(&body);
    w.write_all(&buf).context("writing IPC frame")?;
    w.flush().context("flushing IPC frame")?;
    Ok(())
}

/// Read one frame. `Ok(None)` is a clean EOF *at a frame boundary*
/// (the peer closed the pipe); EOF mid-frame, a hostile length field
/// or an undecodable body is an error — the stream has lost sync and
/// the connection must be poisoned, never resynchronized by guessing.
pub fn read_frame(r: &mut impl Read) -> Result<Option<TlvFile>> {
    let mut len = [0u8; 4];
    let mut got = 0;
    while got < len.len() {
        match r.read(&mut len[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => bail!("IPC frame header truncated ({got} of 4 bytes)"),
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e).context("reading IPC frame header"),
        }
    }
    let len = u32::from_le_bytes(len) as usize;
    ensure!(
        len <= MAX_FRAME_BYTES,
        "IPC frame declares {len} bytes (bound {MAX_FRAME_BYTES}) — \
         corrupt or hostile stream"
    );
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).context("reading IPC frame body")?;
    Ok(Some(TlvFile::parse(&body).context("decoding IPC frame")?))
}

// --- scalar / tensor entry helpers -----------------------------------------

fn split_u64(v: u64) -> [i32; 2] {
    [(v >> 32) as u32 as i32, v as u32 as i32]
}

fn join_u64(hi: i32, lo: i32) -> u64 {
    ((hi as u32 as u64) << 32) | (lo as u32 as u64)
}

fn put_u64(tlv: &mut TlvFile, name: &str, v: u64) -> Result<()> {
    tlv.insert(
        name,
        TlvEntry {
            exp: 0,
            payload: TlvPayload::I32(Tensor::from_vec(
                &[2],
                split_u64(v).to_vec(),
            )),
        },
    )
}

fn get_u64(tlv: &TlvFile, name: &str) -> Result<u64> {
    let t = tlv.get(name)?.as_i32()?;
    ensure!(t.data().len() == 2, "entry '{name}': malformed u64");
    Ok(join_u64(t.data()[0], t.data()[1]))
}

fn put_usize(tlv: &mut TlvFile, name: &str, v: usize) -> Result<()> {
    put_u64(tlv, name, v as u64)
}

fn get_usize(tlv: &TlvFile, name: &str) -> Result<usize> {
    usize::try_from(get_u64(tlv, name)?)
        .with_context(|| format!("entry '{name}': value exceeds usize"))
}

fn put_str(tlv: &mut TlvFile, name: &str, s: &str) -> Result<()> {
    let bytes: Vec<i8> = s.bytes().map(|b| b as i8).collect();
    tlv.insert(
        name,
        TlvEntry {
            exp: 0,
            payload: TlvPayload::I8(Tensor::from_vec(&[bytes.len()], bytes)),
        },
    )
}

fn get_str(tlv: &TlvFile, name: &str) -> Result<String> {
    let t = tlv.get(name)?.as_i8()?;
    String::from_utf8(t.data().iter().map(|&b| b as u8).collect())
        .with_context(|| format!("entry '{name}': non-utf8 string"))
}

fn put_f64(tlv: &mut TlvFile, name: &str, v: f64) -> Result<()> {
    tlv.insert(
        name,
        TlvEntry {
            exp: 0,
            payload: TlvPayload::F64(Tensor::from_vec(&[1], vec![v])),
        },
    )
}

fn get_f64(tlv: &TlvFile, name: &str) -> Result<f64> {
    let t = tlv.get(name)?.as_f64()?;
    ensure!(t.data().len() == 1, "entry '{name}': malformed f64");
    Ok(t.data()[0])
}

fn put_qtensor(tlv: &mut TlvFile, name: &str, q: &QTensor) -> Result<()> {
    // O(1): the entry shares the CoW payload handle; bytes are only
    // touched when the frame is serialized
    tlv.insert(
        name,
        TlvEntry { exp: q.exp, payload: TlvPayload::I16(q.t.clone()) },
    )
}

fn get_qtensor(tlv: &TlvFile, name: &str) -> Result<QTensor> {
    let e = tlv.get(name)?;
    Ok(QTensor { t: e.as_i16()?.clone(), exp: e.exp })
}

fn ok_frame() -> TlvFile {
    let mut f = TlvFile::default();
    put_u64(&mut f, KEY_OK, 1).expect("fresh frame");
    f
}

fn err_frame(e: &anyhow::Error) -> TlvFile {
    let mut f = TlvFile::default();
    put_u64(&mut f, KEY_OK, 0).expect("fresh frame");
    put_str(&mut f, KEY_ERR, &format!("{e:#}")).expect("fresh frame");
    f
}

// --- request / reply encoding ----------------------------------------------

/// Encode a batched segment call. Carries the segment *name* (ids are
/// per-process; a restarted worker re-resolves) and one `in.{i}.{j}`
/// entry per input tensor — exact quantized values, so the worker
/// computes bit-identically to an in-process backend.
fn encode_run_batch(name: &str, batch: &[Vec<QTensor>]) -> Result<TlvFile> {
    let mut f = TlvFile::default();
    put_str(&mut f, KEY_OP, OP_RUN_BATCH)?;
    put_str(&mut f, "segment", name)?;
    put_usize(&mut f, "width", batch.len())?;
    for (i, ins) in batch.iter().enumerate() {
        put_usize(&mut f, &format!("in.{i}.n"), ins.len())?;
        for (j, q) in ins.iter().enumerate() {
            put_qtensor(&mut f, &format!("in.{i}.{j}"), q)?;
        }
    }
    Ok(f)
}

fn decode_reply_outs(frame: &TlvFile) -> Result<(Vec<Vec<QTensor>>, f64)> {
    if get_u64(frame, KEY_OK)? == 0 {
        let msg = get_str(frame, KEY_ERR)
            .unwrap_or_else(|_| "worker reported an unnamed error".into());
        bail!("worker: {msg}");
    }
    if frame.entries.contains_key("width") {
        let width = get_usize(frame, "width")?;
        let mut outs = Vec::with_capacity(width.min(4096));
        for i in 0..width {
            let n = get_usize(frame, &format!("out.{i}.n"))?;
            let mut slot = Vec::with_capacity(n.min(64));
            for j in 0..n {
                slot.push(get_qtensor(frame, &format!("out.{i}.{j}"))?);
            }
            outs.push(slot);
        }
        Ok((outs, get_f64(frame, "exec_s").unwrap_or(0.0)))
    } else {
        Ok((Vec::new(), 0.0)) // ping-style bare ok
    }
}

/// Turn a reply frame into the completion the submit/await contract
/// hands to waiters. The execution interval is reconstructed from the
/// worker-side execution seconds (arrival minus exec), so the overlap
/// profiler sees the window the work actually ran in.
fn decode_completion(frame: &TlvFile) -> HwCompletion {
    let end = Instant::now();
    match decode_reply_outs(frame) {
        Ok((outs, exec_s)) => {
            let start = if exec_s.is_finite() && exec_s >= 0.0 {
                end.checked_sub(Duration::from_secs_f64(exec_s)).unwrap_or(end)
            } else {
                end
            };
            HwCompletion { outs: Ok(outs), start, end }
        }
        Err(e) => HwCompletion { outs: Err(e), start: end, end },
    }
}

// --- the worker process handle (parent side) -------------------------------

struct PendingReply {
    tx: Sender<HwCompletion>,
    since: Instant,
}

/// Connection state shared between callers, the reader thread and the
/// supervisor's monitor.
struct WireShared {
    pending: Mutex<VecDeque<PendingReply>>,
    last_beat: Mutex<Instant>,
    alive: AtomicBool,
}

/// One live `fadec worker` child: its pipes, the reader thread that
/// demultiplexes heartbeats from FIFO replies, and the liveness signals
/// the [`Supervisor`](super::supervisor::Supervisor) monitors. Owned by
/// a supervisor; replaced wholesale on restart (a `SegmentId` resolved
/// against the parent-side manifest stays valid — only names cross the
/// wire).
pub struct WorkerProcess {
    child: Mutex<Child>,
    writer: Mutex<Option<ChildStdin>>,
    shared: Arc<WireShared>,
    reader: Option<JoinHandle<()>>,
    manifest_fp: u64,
    qp_fp: u64,
}

impl WorkerProcess {
    /// Spawn a worker and run the handshake: send `hello` (seed, conv
    /// threads, heartbeat period), read back the worker's manifest and
    /// parameter fingerprints. The child is killed and reaped on any
    /// handshake failure — no zombie survives a bad start.
    pub fn spawn(
        exe: &Path,
        seed: u64,
        conv_threads: usize,
        heartbeat: Duration,
    ) -> Result<WorkerProcess> {
        let mut child = Command::new(exe)
            .arg("worker")
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .with_context(|| {
                format!("spawning worker process {}", exe.display())
            })?;
        let stdin = child.stdin.take().expect("piped stdin");
        let stdout = child.stdout.take().expect("piped stdout");
        match Self::handshake(stdin, stdout, seed, conv_threads, heartbeat) {
            Ok((stdin, stdout, manifest_fp, qp_fp)) => {
                let shared = Arc::new(WireShared {
                    pending: Mutex::new(VecDeque::new()),
                    last_beat: Mutex::new(Instant::now()),
                    alive: AtomicBool::new(true),
                });
                let reader = {
                    let shared = Arc::clone(&shared);
                    thread::Builder::new()
                        .name("fadec-ipc-reader".into())
                        .spawn(move || reader_loop(stdout, shared))
                        .context("spawning IPC reader thread")?
                };
                Ok(WorkerProcess {
                    child: Mutex::new(child),
                    writer: Mutex::new(Some(stdin)),
                    shared,
                    reader: Some(reader),
                    manifest_fp,
                    qp_fp,
                })
            }
            Err(e) => {
                let _ = child.kill();
                let _ = child.wait();
                Err(e.context("worker handshake"))
            }
        }
    }

    fn handshake(
        mut stdin: ChildStdin,
        mut stdout: ChildStdout,
        seed: u64,
        conv_threads: usize,
        heartbeat: Duration,
    ) -> Result<(ChildStdin, ChildStdout, u64, u64)> {
        let mut hello = TlvFile::default();
        put_str(&mut hello, KEY_OP, OP_HELLO)?;
        put_u64(&mut hello, "proto", PROTO_VERSION)?;
        put_u64(&mut hello, "seed", seed)?;
        put_usize(&mut hello, "conv_threads", conv_threads)?;
        put_u64(&mut hello, "heartbeat_ms", heartbeat.as_millis() as u64)?;
        write_frame(&mut stdin, &hello)?;
        let reply = loop {
            match read_frame(&mut stdout)? {
                None => bail!("worker closed the pipe before replying"),
                Some(f) if f.entries.contains_key(KEY_BEAT) => continue,
                Some(f) => break f,
            }
        };
        if get_u64(&reply, KEY_OK)? == 0 {
            bail!(
                "worker rejected the handshake: {}",
                get_str(&reply, KEY_ERR).unwrap_or_else(|_| "unknown".into())
            );
        }
        let manifest_fp = get_u64(&reply, "manifest_fp")?;
        let qp_fp = get_u64(&reply, "qp_fp")?;
        Ok((stdin, stdout, manifest_fp, qp_fp))
    }

    /// Fingerprints the worker reported at handshake (checked against
    /// the parent's local catalogue by the supervisor).
    pub fn manifest_fp(&self) -> u64 {
        self.manifest_fp
    }

    pub fn qp_fp(&self) -> u64 {
        self.qp_fp
    }

    /// Whether the connection is live (false after EOF, a protocol
    /// error, a failed write, or [`WorkerProcess::kill`]).
    pub fn alive(&self) -> bool {
        self.shared.alive.load(Ordering::Acquire)
    }

    /// Send a request that expects a reply; the returned receiver gets
    /// the completion when the reader matches it in FIFO order. The
    /// pending registration and the pipe write happen under the writer
    /// lock, so registration order always equals wire order.
    pub fn send_expecting_reply(
        &self,
        frame: &TlvFile,
    ) -> Result<Receiver<HwCompletion>> {
        let mut w = self.writer.lock().expect("ipc writer poisoned");
        ensure!(self.alive(), "worker process is down");
        let w = w.as_mut().context("worker stdin closed")?;
        let (tx, rx) = mpsc::channel();
        self.shared
            .pending
            .lock()
            .expect("ipc pending poisoned")
            .push_back(PendingReply { tx, since: Instant::now() });
        if let Err(e) = write_frame(w, frame) {
            // a torn request desyncs the stream: poison the connection
            // (the reader will fail the pending entry when it notices)
            self.shared.alive.store(false, Ordering::Release);
            return Err(e.context("writing request to worker"));
        }
        Ok(rx)
    }

    /// Send a fire-and-forget request (injectors, conv-thread hints,
    /// shutdown) — nothing is registered, so no reply is expected.
    pub fn send_oneway(&self, frame: &TlvFile) -> Result<()> {
        let mut w = self.writer.lock().expect("ipc writer poisoned");
        ensure!(self.alive(), "worker process is down");
        let w = w.as_mut().context("worker stdin closed")?;
        if let Err(e) = write_frame(w, frame) {
            self.shared.alive.store(false, Ordering::Release);
            return Err(e.context("writing request to worker"));
        }
        Ok(())
    }

    /// SIGKILL the child (the crash injector, and the supervisor's
    /// response to a hang). The connection is poisoned immediately; the
    /// reader fails every pending wait when the EOF lands.
    pub fn kill(&self) {
        self.shared.alive.store(false, Ordering::Release);
        if let Ok(mut child) = self.child.lock() {
            let _ = child.kill();
        }
    }

    /// Age of the newest heartbeat (staleness = a frozen worker).
    pub fn last_beat_age(&self) -> Duration {
        self.shared.last_beat.lock().expect("beat poisoned").elapsed()
    }

    /// Age of the oldest request still awaiting its reply (staleness =
    /// a stalled serve loop, even while heartbeats keep arriving).
    pub fn oldest_pending_age(&self) -> Option<Duration> {
        self.shared
            .pending
            .lock()
            .expect("ipc pending poisoned")
            .front()
            .map(|p| p.since.elapsed())
    }

    /// Requests in flight (the queue-depth signal for placement).
    pub fn pending_len(&self) -> usize {
        self.shared.pending.lock().expect("ipc pending poisoned").len()
    }
}

impl Drop for WorkerProcess {
    fn drop(&mut self) {
        // best-effort polite shutdown, then unconditional reclaim: a
        // wedged worker never honours the request, and teardown must
        // not block behind one
        let mut bye = TlvFile::default();
        if put_str(&mut bye, KEY_OP, OP_SHUTDOWN).is_ok() {
            if let Ok(mut w) = self.writer.lock() {
                if let Some(w) = w.as_mut() {
                    let _ = write_frame(w, &bye);
                }
                *w = None; // close stdin: EOF is the worker's exit signal
            }
        }
        self.shared.alive.store(false, Ordering::Release);
        if let Ok(mut child) = self.child.lock() {
            let _ = child.kill();
            let _ = child.wait();
        }
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}

fn reader_loop(mut out: ChildStdout, shared: Arc<WireShared>) {
    loop {
        match read_frame(&mut out) {
            Ok(Some(frame)) => {
                if frame.entries.contains_key(KEY_BEAT) {
                    *shared.last_beat.lock().expect("beat poisoned") =
                        Instant::now();
                    continue;
                }
                let completion = decode_completion(&frame);
                let pending = shared
                    .pending
                    .lock()
                    .expect("ipc pending poisoned")
                    .pop_front();
                match pending {
                    Some(p) => {
                        // the waiter may have timed out and dropped its
                        // receiver — the queue entry is consumed either
                        // way, so FIFO matching stays aligned
                        let _ = p.tx.send(completion);
                    }
                    // a reply with no request: the stream is desynced
                    None => break,
                }
            }
            // EOF (exit, kill) or a corrupt frame: poison, never guess
            Ok(None) | Err(_) => break,
        }
    }
    shared.alive.store(false, Ordering::Release);
    // dropping the senders disconnects every waiter immediately — a
    // crashed worker surfaces as a retryable wait fault, not a hang
    shared.pending.lock().expect("ipc pending poisoned").clear();
}

/// Locate the `fadec` binary to spawn workers from: the
/// `FADEC_WORKER_EXE` override, else next to the current executable
/// (hopping out of `deps/` / `examples/` for test and example
/// binaries, which live one directory below the bin target).
pub fn worker_exe() -> Result<PathBuf> {
    if let Ok(p) = std::env::var("FADEC_WORKER_EXE") {
        return Ok(PathBuf::from(p));
    }
    let mut dir = std::env::current_exe().context("locating current exe")?;
    dir.pop();
    if dir
        .file_name()
        .is_some_and(|d| d == "deps" || d == "examples")
    {
        dir.pop();
    }
    let exe = dir.join(if cfg!(windows) { "fadec.exe" } else { "fadec" });
    ensure!(
        exe.is_file(),
        "worker executable {} not found — build the `fadec` bin or set \
         FADEC_WORKER_EXE",
        exe.display()
    );
    Ok(exe)
}

// --- IpcBackend ------------------------------------------------------------

/// [`HwBackend`] over a supervised worker process. The segment
/// catalogue and quantization parameters are materialized locally from
/// the same `(synthetic, seed)` recipe the worker uses — verified
/// fingerprint-for-fingerprint at every handshake — so `resolve` /
/// `segment_desc` / `manifest` never cross the wire and a [`SegmentId`]
/// survives worker restarts (only names are ever sent).
pub struct IpcBackend {
    manifest: Manifest,
    qp: Arc<QuantParams>,
    index: HashMap<String, usize>,
    sup: Supervisor,
    payload: AtomicU64,
}

impl IpcBackend {
    /// Spawn (and supervise) a worker hosting `RefBackend::synthetic`
    /// over `opts.seed`, and verify its fingerprints match the local
    /// catalogue.
    pub fn connect(opts: SupervisorOptions) -> Result<IpcBackend> {
        let manifest = Manifest::synthetic();
        let qp = Arc::new(QuantParams::synthetic(&manifest, opts.seed));
        let sup =
            Supervisor::start(manifest.fingerprint(), qp.fingerprint(), opts)?;
        let index = manifest
            .segments
            .iter()
            .enumerate()
            .map(|(i, d)| (d.name.clone(), i))
            .collect();
        Ok(IpcBackend { manifest, qp, index, sup, payload: AtomicU64::new(0) })
    }

    /// The parameter set streams over this backend quantize against
    /// (value-identical to the worker's, by the fingerprint check).
    pub fn qp(&self) -> &Arc<QuantParams> {
        &self.qp
    }

    /// The child's supervisor (restart budget, liveness stats, and the
    /// fault injectors the supervision tests drive).
    pub fn supervisor(&self) -> &Supervisor {
        &self.sup
    }

    /// Crash injector: SIGKILL the current worker mid-flight.
    pub fn kill_worker(&self) {
        self.sup.kill_worker();
    }

    /// Hang injector: park the worker's serve loop (heartbeats keep
    /// flowing, so only the per-wait deadline can catch it).
    pub fn stall_worker(&self) -> Result<()> {
        let mut f = TlvFile::default();
        put_str(&mut f, KEY_OP, OP_STALL)?;
        self.sup.send_oneway(&f)
    }

    /// Freeze injector: park serve loop *and* heartbeats (the SIGSTOP
    /// analog) — caught by heartbeat-miss detection.
    pub fn freeze_worker(&self) -> Result<()> {
        let mut f = TlvFile::default();
        put_str(&mut f, KEY_OP, OP_FREEZE)?;
        self.sup.send_oneway(&f)
    }

    /// Blocking liveness round-trip (tests).
    pub fn ping(&self) -> Result<()> {
        let mut f = TlvFile::default();
        put_str(&mut f, KEY_OP, OP_PING)?;
        let rx = self.sup.submit(&f)?;
        let c = rx.recv().context("worker dropped the ping")?;
        c.outs.map(|_| ())
    }
}

impl HwBackend for IpcBackend {
    fn kind(&self) -> &'static str {
        "ipc"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn resolve(&self, name: &str) -> Result<SegmentId> {
        self.index
            .get(name)
            .map(|&i| SegmentId(i))
            .with_context(|| format!("segment '{name}' not in catalogue"))
    }

    fn segment_desc(&self, id: SegmentId) -> &SegmentDesc {
        &self.manifest.segments[id.0]
    }

    fn run(&self, id: SegmentId, inputs: &[&QTensor]) -> Result<Vec<QTensor>> {
        let owned: Vec<QTensor> = inputs.iter().copied().cloned().collect();
        self.submit(id, owned)?.wait()
    }

    fn run_batch(
        &self,
        id: SegmentId,
        batch: &[Vec<&QTensor>],
    ) -> Result<Vec<Vec<QTensor>>> {
        let owned: Vec<Vec<QTensor>> = batch
            .iter()
            .map(|ins| ins.iter().copied().cloned().collect())
            .collect();
        self.submit_batch(id, owned)?.wait_batch()
    }

    fn submit_batch(
        &self,
        id: SegmentId,
        batch: Vec<Vec<QTensor>>,
    ) -> Result<SubmitHandle> {
        ensure!(
            id.0 < self.manifest.segments.len(),
            "segment id {} out of range",
            id.0
        );
        let desc = &self.manifest.segments[id.0];
        // validate parent-side against the fingerprint-checked local
        // manifest: deterministic errors surface without a round-trip,
        // and a failed submission provably never reached the worker
        let mut bytes = 0u64;
        for ins in &batch {
            let refs: Vec<&QTensor> = ins.iter().collect();
            check_inputs(desc, &refs)?;
            bytes += ins.iter().map(|q| (q.t.len() * 2) as u64).sum::<u64>();
        }
        let frame = encode_run_batch(&desc.name, &batch)?;
        let rx = self.sup.submit(&frame).with_context(|| {
            format!("submitting segment {} to the worker process", desc.name)
        })?;
        self.payload.fetch_add(bytes, Ordering::Relaxed);
        Ok(SubmitHandle::queued(rx))
    }

    fn queue_depth(&self) -> usize {
        self.sup.queue_depth()
    }

    fn submit_payload_bytes(&self) -> u64 {
        self.payload.load(Ordering::Relaxed)
    }

    fn set_conv_threads(&self, threads: usize) {
        self.sup.set_conv_threads(threads);
        // best-effort live hint; results are bit-identical for any
        // thread count, so a lost hint costs latency, never exactness
        let mut f = TlvFile::default();
        if put_str(&mut f, KEY_OP, OP_CONV).is_ok()
            && put_usize(&mut f, "threads", threads).is_ok()
        {
            let _ = self.sup.send_oneway(&f);
        }
    }

    fn supervisor_stats(&self) -> Option<SupervisorStats> {
        Some(self.sup.stats())
    }
}

// --- the worker side (`fadec worker`) --------------------------------------

fn write_frame_locked(out: &Mutex<io::Stdout>, frame: &TlvFile) -> Result<()> {
    let mut w = out.lock().expect("stdout poisoned");
    write_frame(&mut *w, frame)
}

fn handle_run_batch(be: &super::RefBackend, req: &TlvFile) -> Result<TlvFile> {
    let name = get_str(req, "segment")?;
    let id = be.resolve(&name)?;
    let width = get_usize(req, "width")?;
    ensure!(width <= 4096, "run_batch width {width} exceeds 4096");
    let mut batch: Vec<Vec<QTensor>> = Vec::with_capacity(width);
    for i in 0..width {
        let n = get_usize(req, &format!("in.{i}.n"))?;
        ensure!(n <= 64, "slot {i}: {n} inputs exceeds 64");
        let mut ins = Vec::with_capacity(n);
        for j in 0..n {
            ins.push(get_qtensor(req, &format!("in.{i}.{j}"))?);
        }
        batch.push(ins);
    }
    let refs: Vec<Vec<&QTensor>> =
        batch.iter().map(|ins| ins.iter().collect()).collect();
    let t0 = Instant::now();
    let outs = be.run_batch(id, &refs)?;
    let exec_s = t0.elapsed().as_secs_f64();
    let mut reply = ok_frame();
    put_usize(&mut reply, "width", outs.len())?;
    for (i, slot) in outs.iter().enumerate() {
        put_usize(&mut reply, &format!("out.{i}.n"), slot.len())?;
        for (j, q) in slot.iter().enumerate() {
            put_qtensor(&mut reply, &format!("out.{i}.{j}"), q)?;
        }
    }
    put_f64(&mut reply, "exec_s", exec_s)?;
    Ok(reply)
}

/// Entry point of the `fadec worker` subcommand: host a seeded
/// synthetic `RefBackend` and serve frames from stdin to stdout until
/// EOF or `shutdown`. All configuration arrives in the `hello` frame;
/// stderr stays an ordinary diagnostic stream. Never intended for
/// interactive use — the parent is a [`WorkerProcess`].
pub fn worker_main(_args: &Args) -> Result<()> {
    let mut input = io::stdin().lock();
    let stdout = Arc::new(Mutex::new(io::stdout()));
    let hello = read_frame(&mut input)?
        .context("parent closed the pipe before the handshake")?;
    let setup = (|| -> Result<(u64, usize, u64)> {
        ensure!(
            get_str(&hello, KEY_OP)? == OP_HELLO,
            "first frame must be hello"
        );
        let proto = get_u64(&hello, "proto")?;
        ensure!(
            proto == PROTO_VERSION,
            "protocol version {proto} != {PROTO_VERSION} — \
             parent/worker build skew"
        );
        Ok((
            get_u64(&hello, "seed")?,
            get_usize(&hello, "conv_threads")?,
            get_u64(&hello, "heartbeat_ms")?,
        ))
    })();
    let (seed, conv_threads, heartbeat_ms) = match setup {
        Ok(v) => v,
        Err(e) => {
            let _ = write_frame_locked(&stdout, &err_frame(&e));
            return Err(e);
        }
    };
    let be = super::RefBackend::synthetic(seed);
    if conv_threads > 0 {
        be.set_conv_threads(conv_threads);
    }
    let mut reply = ok_frame();
    put_u64(&mut reply, "manifest_fp", be.manifest().fingerprint())?;
    put_u64(&mut reply, "qp_fp", be.qp().fingerprint())?;
    write_frame_locked(&stdout, &reply)?;
    // heartbeats ride the same pipe as replies (frame-atomic under the
    // stdout mutex); `frozen` silences them without killing the thread
    let frozen = Arc::new(AtomicBool::new(false));
    let done = Arc::new(AtomicBool::new(false));
    if heartbeat_ms > 0 {
        let (out, frozen, done) =
            (Arc::clone(&stdout), Arc::clone(&frozen), Arc::clone(&done));
        thread::Builder::new()
            .name("fadec-worker-beat".into())
            .spawn(move || {
                let mut n = 0u64;
                while !done.load(Ordering::Acquire) {
                    thread::sleep(Duration::from_millis(heartbeat_ms));
                    if frozen.load(Ordering::Acquire) {
                        continue;
                    }
                    n += 1;
                    let mut f = TlvFile::default();
                    if put_u64(&mut f, KEY_BEAT, n).is_err()
                        || write_frame_locked(&out, &f).is_err()
                    {
                        break; // parent went away; serve loop sees EOF
                    }
                }
            })
            .context("spawning heartbeat thread")?;
    }
    loop {
        let Some(req) = read_frame(&mut input)? else {
            break; // parent closed stdin: clean exit
        };
        let op = match get_str(&req, KEY_OP) {
            Ok(op) => op,
            Err(e) => {
                write_frame_locked(&stdout, &err_frame(&e))?;
                continue;
            }
        };
        match op.as_str() {
            OP_RUN_BATCH => {
                let reply = match handle_run_batch(&be, &req) {
                    Ok(r) => r,
                    Err(e) => err_frame(&e),
                };
                write_frame_locked(&stdout, &reply)?;
            }
            OP_PING => write_frame_locked(&stdout, &ok_frame())?,
            OP_CONV => {
                if let Ok(n) = get_usize(&req, "threads") {
                    be.set_conv_threads(n);
                }
            }
            OP_STALL => loop {
                // induced hang: the serve loop wedges but heartbeats
                // keep flowing — only a per-wait deadline catches this
                thread::sleep(Duration::from_millis(50));
            },
            OP_FREEZE => {
                frozen.store(true, Ordering::Release);
                loop {
                    // SIGSTOP analog: no replies *and* no heartbeats
                    thread::sleep(Duration::from_millis(50));
                }
            }
            OP_SHUTDOWN => break,
            other => {
                let e = anyhow!("unknown op '{other}'");
                write_frame_locked(&stdout, &err_frame(&e))?;
            }
        }
    }
    done.store(true, Ordering::Release);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip(frame: &TlvFile) -> TlvFile {
        let mut buf = Vec::new();
        write_frame(&mut buf, frame).unwrap();
        read_frame(&mut Cursor::new(buf)).unwrap().expect("one frame")
    }

    #[test]
    fn frame_roundtrip_and_clean_eof() {
        let mut f = TlvFile::default();
        put_u64(&mut f, "a", u64::MAX - 7).unwrap();
        put_str(&mut f, "b", "fe_fs").unwrap();
        put_f64(&mut f, "c", -0.125).unwrap();
        let back = roundtrip(&f);
        assert_eq!(get_u64(&back, "a").unwrap(), u64::MAX - 7);
        assert_eq!(get_str(&back, "b").unwrap(), "fe_fs");
        assert_eq!(get_f64(&back, "c").unwrap(), -0.125);
        // empty pipe: clean EOF at the frame boundary is None, not Err
        assert!(read_frame(&mut Cursor::new(Vec::new())).unwrap().is_none());
    }

    #[test]
    fn torn_and_hostile_frames_are_rejected() {
        let mut f = TlvFile::default();
        put_str(&mut f, "x", "payload").unwrap();
        let mut buf = Vec::new();
        write_frame(&mut buf, &f).unwrap();
        // every strict prefix of a frame is an error (truncated header
        // or truncated body), never a silent None past offset 0
        for cut in [1, 3, 4, 5, buf.len() - 1] {
            let r = read_frame(&mut Cursor::new(buf[..cut].to_vec()));
            assert!(r.is_err(), "prefix of {cut} bytes must not parse");
        }
        // a hostile length field is rejected before allocation
        let mut hostile = Vec::new();
        hostile.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = read_frame(&mut Cursor::new(hostile)).unwrap_err();
        assert!(format!("{err:#}").contains("bound"), "{err:#}");
        // a corrupt body is a decode error, not UB
        let mut corrupt = buf.clone();
        let last = corrupt.len() - 1;
        corrupt[last] ^= 0xFF;
        corrupt[5] ^= 0x55;
        assert!(read_frame(&mut Cursor::new(corrupt)).is_err());
    }

    #[test]
    fn u64_halves_and_strings_roundtrip() {
        for v in [0u64, 1, u64::MAX, 0xDEAD_BEEF_0BAD_F00D] {
            let [hi, lo] = split_u64(v);
            assert_eq!(join_u64(hi, lo), v);
        }
        let mut f = TlvFile::default();
        put_str(&mut f, "s", "xäy").unwrap();
        assert_eq!(get_str(&roundtrip(&f), "s").unwrap(), "xäy");
    }

    #[test]
    fn run_batch_request_roundtrips_exact_tensors() {
        let q = QTensor {
            t: Tensor::from_vec(&[2, 3], vec![-7i16, 0, 1, 2, i16::MAX, -1]),
            exp: -9,
        };
        let req =
            encode_run_batch("cve", &[vec![q.clone(), q.clone()], vec![q.clone()]])
                .unwrap();
        let back = roundtrip(&req);
        assert_eq!(get_str(&back, KEY_OP).unwrap(), OP_RUN_BATCH);
        assert_eq!(get_str(&back, "segment").unwrap(), "cve");
        assert_eq!(get_usize(&back, "width").unwrap(), 2);
        assert_eq!(get_usize(&back, "in.0.n").unwrap(), 2);
        assert_eq!(get_usize(&back, "in.1.n").unwrap(), 1);
        let b = get_qtensor(&back, "in.1.0").unwrap();
        assert_eq!(b.exp, q.exp);
        assert_eq!(b.t.shape(), q.t.shape());
        assert_eq!(b.t.data(), q.t.data());
    }

    #[test]
    fn error_replies_decode_to_contextual_errors() {
        let e = anyhow!("segment exploded");
        let frame = roundtrip(&err_frame(&e));
        let c = decode_completion(&frame);
        let err = c.outs.unwrap_err();
        assert!(format!("{err:#}").contains("segment exploded"), "{err:#}");
        // a bare ok (ping reply) decodes to an empty batch
        let ok = roundtrip(&ok_frame());
        assert!(decode_completion(&ok).outs.unwrap().is_empty());
    }
}
