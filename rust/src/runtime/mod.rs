//! Backend layer — the "programmable logic" abstraction of the stack.
//!
//! [`HwBackend`] is the contract the coordinator schedules against: a
//! catalogue of FSM-sequenced segments (the analog of FADEC's accelerator
//! stage groups) executed many times per frame. Two implementations:
//!
//! * [`HwRuntime`] — loads the AOT artifacts (`artifacts/*.hlo.txt`) and
//!   executes them on the PJRT CPU client, compiled once at startup (the
//!   analog of configuring the bitstream). Interchange is HLO *text*
//!   (not serialized protos): jax >= 0.5 emits 64-bit instruction ids
//!   that xla_extension 0.5.1 rejects; the text parser reassigns ids.
//! * [`RefBackend`] — the pure-software reference in
//!   [`ref_backend`]: the same segment boundaries served by the bit-exact
//!   Rust integer mirrors, runnable with no `artifacts/` directory.
//!
//! Segment lookup is split in two: [`HwBackend::resolve`] turns a name
//! into a [`SegmentId`] once at pipeline construction, and the hot
//! [`HwBackend::run`] path is a plain index — no per-call map lookup.
//!
//! # The submit/await contract
//!
//! [`HwBackend::submit`] / [`HwBackend::submit_batch`] enqueue a segment
//! and return a [`SubmitHandle`] without waiting for the result;
//! [`SubmitHandle::wait_batch`] (or the trait-level [`HwBackend::wait`])
//! blocks until the segment completes. The contract:
//!
//! * **Ownership transfer (the zero-copy data plane)** — `submit*` take
//!   their input batch **by value**: the caller moves its `QTensor`
//!   handles into the submission and the backend owns them until the
//!   segment retires. Tensor payloads are Arc-backed CoW handles
//!   (`tensor` module docs), so a caller that still needs an input after
//!   submitting clones the handle in O(1) — either way *no payload bytes
//!   are copied or allocated on the submit path*. An async backend
//!   enqueues the received handles as-is (the DMA-descriptor analog:
//!   the queue carries pointers, not pixels) and drops them once the
//!   segment has executed; it must not mutate them (inputs are read-only
//!   — CoW would make a mutation correct but it would also deep-copy,
//!   which this path exists to avoid).
//! * **Default-eager semantics** — the provided implementations execute
//!   the segment *inside* `submit*` via [`HwBackend::run_batch`] and
//!   return an already-complete handle. Any backend that only implements
//!   `run`/`run_batch` (e.g. [`HwRuntime`], or a third-party impl) is
//!   therefore automatically submit/await-correct: the pipelined serving
//!   paths degrade to the lockstep schedule, bit-identically.
//! * **In-order completion** — an async implementation must execute
//!   submissions strictly in submission order (one PL, one command
//!   queue). Handles may be *waited* in any order — each handle owns its
//!   completion — but execution order is FIFO, so waiting handle N
//!   implies every earlier submission has also finished executing.
//! * **Bit-exactness** — `submit_batch(id, batch)` then `wait` must
//!   return exactly what `run_batch(id, batch)` returns. Submission is a
//!   scheduling optimisation, never a semantic one.
//! * **Error surfacing** — input validation errors may surface at either
//!   `submit*` (the DMA-descriptor check happens when the command is
//!   queued) or at `wait`; execution errors always surface at `wait`.
//!
//! `RefBackend` overrides `submit_batch` with a real async
//! implementation: a dedicated backend worker thread drains a FIFO job
//! queue, so submitted segments execute while the caller runs software
//! stages — the overlap `StreamServer::run_pipelined` is built on.
//!
//! # Sharding contract (multi-backend deployments)
//!
//! A fleet of backend instances ("shards", see `coordinator::ShardRouter`)
//! adds two rules on top of the submit/await contract:
//!
//! * **Per-shard handle validity** — a [`SegmentId`] is an index into the
//!   manifest order *of the backend that resolved it* and is meaningless
//!   on any other instance, even one serving a value-identical catalogue.
//!   Anything that moves between shards must carry segment *names* and
//!   re-resolve on arrival; the router does this by giving every shard
//!   its own `PipelineEngine` (hence its own resolved handle map) and
//!   never sharing ids across engines.
//! * **Migration ordering** — a `StreamSession` may be handed from shard
//!   A to shard B only *between rounds*: every submission the session
//!   contributed to on A must have been waited (or its round abandoned
//!   wholesale before the Commit stage) before the session value moves.
//!   Sessions are mutated only at Commit, so a between-rounds handoff is
//!   a plain value move and the receiving shard's first round on the
//!   stream is bit-identical to the round the donor would have run.
//!
//! Shard-level accounting ([`HwBackend::queue_depth`],
//! [`HwBackend::submit_payload_bytes`]) is intentionally approximate
//! (Relaxed counters): it feeds placement heuristics and reports, never
//! correctness decisions.
//!
//! # The fault/retry contract (PR 7)
//!
//! Real devices fault; the serving stack retries. The rules that make a
//! retry safe:
//!
//! * **Which errors are retryable** — any error surfaced at `submit*`
//!   or at `wait` *before the session's Commit stage* is retryable:
//!   sessions are mutated only at Commit (see the migration-ordering
//!   rule above), so a failed submission has, by construction, not
//!   changed any cross-frame state. Input-validation errors (shape /
//!   exponent mismatches from [`check_inputs`]) are deterministic and
//!   therefore *not worth* retrying, but retrying them is still safe —
//!   the retry policy bounds attempts rather than classifying errors.
//! * **Idempotence requirement on `submit*`** — a backend must treat a
//!   failed submission as if it never happened: inputs are read-only
//!   (never mutated, per the ownership-transfer rule), no partial
//!   outputs escape, and internal accounting (queue depth, payload
//!   bytes) must not leak. The caller re-submits *cloned handles* of
//!   the same CoW payloads (O(1)), so attempt N+1 computes exactly what
//!   attempt N would have — bit-exactness under retry is inherited from
//!   bit-exactness of `run_batch`.
//! * **FIFO ordering under retry** — a retried submission is a *new*
//!   submission at the tail of the queue. The failed attempt either
//!   never enqueued (submit error) or completed-with-error in order
//!   (wait error); either way the queue position is consumed and FIFO
//!   order over *successful* completions is preserved. Callers must
//!   not hold handles from the failed attempt across the retry.
//! * **Worker survival** — a queue worker must outlive job failures
//!   *and* job panics: `RefBackend`'s worker catches unwinds and
//!   delivers them as `Err` completions, so one poisoned job can never
//!   wedge the FIFO or leak `queue_depth` (pinned by its
//!   `worker_survives_*` tests).
//!
//! [`chaos::ChaosBackend`] wraps any backend with seeded deterministic
//! faults (submit error, wait error, latency spike, stall,
//! transient-then-heal, death) so every recovery path above is testable
//! from a clean checkout; `coordinator::RetryPolicy` is the consumer of
//! this contract.
//!
//! # Process isolation & supervision (PR 9)
//!
//! The deployment target is a host CPU driving a separate physical
//! device — one that can wedge or need a reset without taking the host
//! down. [`ipc::IpcBackend`] reproduces that fault boundary in
//! software: the backend lives in a `fadec worker` child process and
//! the trait is served over its stdin/stdout pipes, so a segfault,
//! abort, or infinite loop in one shard's backend is *contained* —
//! sibling shards and every session survive. The rules:
//!
//! * **Wire format** — length-prefixed frames (`u32` LE length + a
//!   `data/tlv.rs` body) carrying exact quantized tensors, so
//!   process-isolated serving is bit-identical to in-process serving
//!   by construction. Segment *names* cross the wire, never
//!   [`SegmentId`]s — ids are per-process and must not survive a
//!   restart (the per-shard handle-validity rule, applied to time).
//!   Frame length is bounded and the body inherits the TLV codec's
//!   hostile-input hardening; a torn or corrupt frame *poisons* the
//!   connection (fail every pending wait, kill the worker) — the
//!   stream is never resynchronized by guessing.
//! * **FIFO over the pipe** — the worker serves requests in order on
//!   one thread and the parent's reader matches replies to a FIFO
//!   queue of pending completions, so [`SubmitHandle`]s complete in
//!   submission order exactly as the submit/await contract requires.
//! * **Heartbeats vs deadlines** — the worker emits heartbeat frames
//!   from a dedicated thread. Heartbeat staleness beyond the grace
//!   period means the *process* is gone or frozen (the SIGSTOP
//!   flavor); an unanswered request older than the per-wait deadline
//!   while heartbeats still flow means the *serve loop* is wedged (the
//!   stall flavor). Both are answered with SIGKILL — a wedged child
//!   cannot be reasoned with — and both are distinct counters in
//!   `metrics::SupervisorStats`.
//! * **Supervised restart** — crash detection is EOF on the pipe (the
//!   reader thread fails every pending wait immediately, so a dead
//!   worker surfaces as a retryable fault, never a hang). The
//!   [`supervisor::Supervisor`] respawns the child with exponential
//!   backoff under a bounded restart budget, re-verifying the
//!   manifest/parameter fingerprints at every handshake. Restart is
//!   safe because the worker is stateless between rounds: all session
//!   state lives in the parent and sessions mutate only at Commit, so
//!   the coordinator replays failed rounds bit-exactly.
//! * **Budget exhaustion** — when restarts run out the supervisor
//!   surfaces [`supervisor::BackendDown`]; `coordinator::ShardRouter`
//!   treats that shard as dead and fails its streams over through
//!   checkpoints, same as any shard death.

pub mod chaos;
pub mod ipc;
pub mod ref_backend;
pub mod supervisor;

pub use chaos::{ChaosBackend, ChaosOptions, ChaosSource, ChaosSourceOptions};
pub use ipc::IpcBackend;
pub use ref_backend::RefBackend;
pub use supervisor::{is_backend_down, BackendDown, Supervisor, SupervisorOptions};

use std::collections::HashMap;
use std::path::Path;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::data::manifest::{Manifest, SegmentDesc};
use crate::metrics::SupervisorStats;
use crate::quant::QTensor;
use crate::tensor::Tensor;

/// Pre-resolved handle to one backend segment. Obtained from
/// [`HwBackend::resolve`] once; valid for the lifetime of that backend.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct SegmentId(pub(crate) usize);

impl SegmentId {
    /// Position of the segment in the backend's manifest order.
    pub fn index(self) -> usize {
        self.0
    }
}

/// Result of one completed submission: the per-stream outputs plus the
/// execution interval, timestamped where the work actually ran (the
/// backend worker for async backends, the submitting caller for the
/// default-eager path) — the data behind the cross-round overlap
/// accounting in `coordinator`.
pub struct HwCompletion {
    pub outs: Result<Vec<Vec<QTensor>>>,
    pub start: Instant,
    pub end: Instant,
}

enum HandleState {
    /// Executed eagerly inside `submit*` (the default-impl contract):
    /// the completion is already here.
    Ready(HwCompletion),
    /// Queued on a backend worker; the completion arrives on this
    /// channel when the worker finishes the segment.
    Queued(Receiver<HwCompletion>),
}

/// Handle to one in-flight [`HwBackend::submit`]/
/// [`HwBackend::submit_batch`] call. Consumed by `wait*`; dropping it
/// without waiting abandons the result (the submission still executes —
/// the queue is FIFO and later submissions sit behind it).
pub struct SubmitHandle {
    state: HandleState,
}

impl SubmitHandle {
    /// An already-completed submission (the default eager semantics).
    pub fn ready(outs: Result<Vec<Vec<QTensor>>>, start: Instant, end: Instant) -> Self {
        SubmitHandle { state: HandleState::Ready(HwCompletion { outs, start, end }) }
    }

    /// A submission whose completion will arrive on `rx` (async
    /// backends send one [`HwCompletion`] per job, in execution order).
    pub fn queued(rx: Receiver<HwCompletion>) -> Self {
        SubmitHandle { state: HandleState::Queued(rx) }
    }

    /// Block until the submission completes; returns the batch outputs
    /// plus the execution interval (for the overlap profiler).
    pub fn wait_batch_timed(self) -> Result<(Vec<Vec<QTensor>>, Instant, Instant)> {
        let c = match self.state {
            HandleState::Ready(c) => c,
            HandleState::Queued(rx) => rx.recv().map_err(|_| {
                anyhow::anyhow!(
                    "backend worker dropped before completing a submitted segment"
                )
            })?,
        };
        Ok((c.outs?, c.start, c.end))
    }

    /// Block until the submission completes; batch outputs only.
    pub fn wait_batch(self) -> Result<Vec<Vec<QTensor>>> {
        self.wait_batch_timed().map(|(outs, _, _)| outs)
    }

    /// [`SubmitHandle::wait_batch_timed`] with a timeout: a completion
    /// that hasn't arrived within `deadline` becomes a retryable error
    /// instead of a hang. The abandoned completion, if it ever arrives,
    /// is dropped by the disconnected channel — per the fault/retry
    /// contract the round replays from scratch, so a late result must
    /// never be consumed. Ready (eager) handles never time out.
    pub fn wait_batch_deadline(
        self,
        deadline: Duration,
    ) -> Result<(Vec<Vec<QTensor>>, Instant, Instant)> {
        let c = match self.state {
            HandleState::Ready(c) => c,
            HandleState::Queued(rx) => match rx.recv_timeout(deadline) {
                Ok(c) => c,
                Err(RecvTimeoutError::Timeout) => bail!(
                    "backend wait timed out after {:.3}s — submission \
                     abandoned as a retryable fault",
                    deadline.as_secs_f64()
                ),
                Err(RecvTimeoutError::Disconnected) => bail!(
                    "backend worker dropped before completing a submitted segment"
                ),
            },
        };
        Ok((c.outs?, c.start, c.end))
    }

    /// Await a width-1 submission made with [`HwBackend::submit`].
    pub fn wait(self) -> Result<Vec<QTensor>> {
        let mut outs = self.wait_batch()?;
        anyhow::ensure!(
            outs.len() == 1,
            "wait() on a batch submission of width {}",
            outs.len()
        );
        Ok(outs.pop().expect("length checked"))
    }
}

/// A compute backend serving the manifest's HW segments. One backend
/// instance plays the role of the single configured bitstream; any number
/// of streams may share it (see `coordinator::StreamServer`).
pub trait HwBackend: Send + Sync {
    /// Short backend kind tag ("pjrt", "ref").
    fn kind(&self) -> &'static str;

    /// The segment catalogue + exponent tables this backend serves.
    fn manifest(&self) -> &Manifest;

    /// Resolve a segment name to a handle. Called once per segment at
    /// pipeline construction; the hot path uses only [`HwBackend::run`].
    fn resolve(&self, name: &str) -> Result<SegmentId>;

    /// Descriptor of a resolved segment.
    fn segment_desc(&self, id: SegmentId) -> &SegmentDesc;

    /// Execute a segment with int16 inputs in manifest order; returns
    /// outputs as QTensors with manifest exponents.
    fn run(&self, id: SegmentId, inputs: &[&QTensor]) -> Result<Vec<QTensor>>;

    /// Execute one segment over a batch of input sets (one per stream in
    /// a serving round). `batch[i]` is the `i`-th stream's inputs in
    /// manifest order; `result[i]` is that stream's outputs. Every
    /// element must be bit-identical to `run(id, &batch[i])` — batching
    /// is a latency optimisation, never a semantic one.
    ///
    /// Default: the loop fallback, so every backend is batch-callable.
    /// `RefBackend` overrides this with a real batched implementation
    /// (shared `PackedConv` tap lists, one conv call per layer for the
    /// whole batch).
    fn run_batch(
        &self,
        id: SegmentId,
        batch: &[Vec<&QTensor>],
    ) -> Result<Vec<Vec<QTensor>>> {
        batch.iter().map(|inputs| self.run(id, inputs)).collect()
    }

    /// Submit one segment over a batch without waiting for the result
    /// (see the module docs for the full submit/await contract). The
    /// batch is taken **by value**: the submission owns its input
    /// handles, so an async backend enqueues them without copying a
    /// single payload byte — callers that still need an input clone its
    /// handle (O(1), CoW) before submitting.
    ///
    /// Default: execute eagerly via [`HwBackend::run_batch`] and return
    /// an already-complete handle, so every backend is submit-callable
    /// and bit-identical to its blocking path. Async backends override
    /// this to enqueue the job on a worker and return a queued handle;
    /// execution must stay FIFO in submission order.
    fn submit_batch(
        &self,
        id: SegmentId,
        batch: Vec<Vec<QTensor>>,
    ) -> Result<SubmitHandle> {
        let start = Instant::now();
        let refs: Vec<Vec<&QTensor>> =
            batch.iter().map(|inputs| inputs.iter().collect()).collect();
        let outs = self.run_batch(id, &refs);
        Ok(SubmitHandle::ready(outs, start, Instant::now()))
    }

    /// Width-1 [`HwBackend::submit_batch`]: submit one stream's segment
    /// inputs (moving the handles in); await with [`SubmitHandle::wait`].
    fn submit(&self, id: SegmentId, inputs: Vec<QTensor>) -> Result<SubmitHandle> {
        self.submit_batch(id, vec![inputs])
    }

    /// Blocking await of a submission — a convenience equivalent to
    /// [`SubmitHandle::wait_batch`]. Note the serving paths await their
    /// handles directly (the handle owns its completion channel), so an
    /// override here is *not* an interposition point for them; a backend
    /// whose completions need custom plumbing should build it into the
    /// handle it returns from `submit*` instead.
    fn wait(&self, handle: SubmitHandle) -> Result<Vec<Vec<QTensor>>> {
        handle.wait_batch()
    }

    /// Resolve + run in one call (cold paths and tests).
    fn run_named(&self, name: &str, inputs: &[&QTensor]) -> Result<Vec<QTensor>> {
        self.run(self.resolve(name)?, inputs)
    }

    /// Number of submitted-but-not-yet-completed jobs on this backend's
    /// queue — the occupancy signal shard placement reads. Approximate by
    /// design (sampled from Relaxed counters). Default: 0, correct for
    /// the default-eager `submit_batch` (nothing is ever left queued).
    fn queue_depth(&self) -> usize {
        0
    }

    /// Total payload bytes moved through `submit*` since construction
    /// (the DMA-traffic analog), for per-shard traffic reporting next to
    /// fps. Default: 0 for backends that don't account for it.
    fn submit_payload_bytes(&self) -> u64 {
        0
    }

    /// Hint: stripe software conv output channels over `threads` workers.
    /// Called by `PipelineEngine` construction with
    /// `PipelineOptions::conv_threads` (when non-zero), so the knob works
    /// through every coordinator/server constructor. Results must stay
    /// bit-identical for any value. Default: no-op — hardware backends
    /// bring their own parallelism.
    fn set_conv_threads(&self, _threads: usize) {}

    /// Supervision counters, for backends whose lifecycle is owned by a
    /// [`supervisor::Supervisor`] (restarts, hang detections, downtime).
    /// `None` for in-process backends — the router uses it both to merge
    /// stats into reports and to tell supervised shards apart. Default:
    /// not supervised.
    fn supervisor_stats(&self) -> Option<SupervisorStats> {
        None
    }
}

/// Shape/exponent validation shared by every backend: inputs must match
/// the manifest descriptors exactly (the DMA contract of the PL).
pub(crate) fn check_inputs(desc: &SegmentDesc, inputs: &[&QTensor]) -> Result<()> {
    anyhow::ensure!(
        inputs.len() == desc.inputs.len(),
        "segment {}: {} inputs given, {} expected",
        desc.name,
        inputs.len(),
        desc.inputs.len()
    );
    for (q, d) in inputs.iter().zip(&desc.inputs) {
        anyhow::ensure!(
            q.t.shape() == d.shape.as_slice(),
            "segment {}: input '{}' shape {:?} != manifest {:?}",
            desc.name,
            d.name,
            q.t.shape(),
            d.shape
        );
        anyhow::ensure!(
            q.exp == d.exp,
            "segment {}: input '{}' exponent {} != manifest {}",
            desc.name,
            d.name,
            q.exp,
            d.exp
        );
    }
    Ok(())
}

/// One compiled HW segment.
pub struct Segment {
    pub desc: SegmentDesc,
    exe: xla::PjRtLoadedExecutable,
}

impl Segment {
    /// Execute with int16 inputs in manifest order; returns the outputs
    /// as QTensors with manifest exponents.
    pub fn execute(&self, inputs: &[&QTensor]) -> Result<Vec<QTensor>> {
        check_inputs(&self.desc, inputs)?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (q, d) in inputs.iter().zip(&self.desc.inputs) {
            literals.push(literal_from_i16(&q.t, &d.shape));
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple result
        let parts = tuple.to_tuple()?;
        anyhow::ensure!(
            parts.len() == self.desc.outputs.len(),
            "segment {}: {} outputs returned, {} in manifest",
            self.desc.name,
            parts.len(),
            self.desc.outputs.len()
        );
        let mut out = Vec::with_capacity(parts.len());
        for (lit, d) in parts.into_iter().zip(&self.desc.outputs) {
            let v: Vec<i16> = lit.to_vec::<i16>()?;
            anyhow::ensure!(
                v.len() == d.numel(),
                "segment {}: output '{}' size {} != {:?}",
                self.desc.name,
                d.name,
                v.len(),
                d.shape
            );
            out.push(QTensor {
                t: Tensor::from_vec(&d.shape, v),
                exp: d.exp,
            });
        }
        Ok(out)
    }
}

fn literal_from_i16(t: &Tensor<i16>, shape: &[usize]) -> xla::Literal {
    let dims: Vec<usize> = shape.to_vec();
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.len() * 2)
    };
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S16,
        &dims,
        bytes,
    )
    .expect("literal creation")
}

/// The PL analog: a PJRT CPU client plus every compiled segment, indexed
/// in manifest order (names are resolved once, not per call).
pub struct HwRuntime {
    pub client: xla::PjRtClient,
    segments: Vec<Segment>,
    index: HashMap<String, usize>,
    manifest: Manifest,
    pub compile_seconds: f64,
}

impl HwRuntime {
    /// Load + compile every artifact in the manifest ("flash the
    /// bitstream"). Compilation happens once; execution is reused across
    /// frames, matching the paper's deployment model.
    pub fn load(artifacts_dir: &Path, manifest: &Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let t0 = Instant::now();
        let mut segments = Vec::with_capacity(manifest.segments.len());
        let mut index = HashMap::with_capacity(manifest.segments.len());
        for desc in &manifest.segments {
            let path = artifacts_dir.join(&desc.hlo);
            if !path.is_file() {
                bail!(
                    "artifact {} missing — run `make artifacts`",
                    path.display()
                );
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", desc.name))?;
            index.insert(desc.name.clone(), segments.len());
            segments.push(Segment { desc: desc.clone(), exe });
        }
        Ok(HwRuntime {
            client,
            segments,
            index,
            manifest: manifest.clone(),
            compile_seconds: t0.elapsed().as_secs_f64(),
        })
    }

    pub fn segment(&self, name: &str) -> Result<&Segment> {
        let idx = self
            .index
            .get(name)
            .with_context(|| format!("segment '{name}' not loaded"))?;
        Ok(&self.segments[*idx])
    }
}

impl HwBackend for HwRuntime {
    fn kind(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn resolve(&self, name: &str) -> Result<SegmentId> {
        self.index
            .get(name)
            .map(|&i| SegmentId(i))
            .with_context(|| format!("segment '{name}' not loaded"))
    }

    fn segment_desc(&self, id: SegmentId) -> &SegmentDesc {
        &self.segments[id.0].desc
    }

    fn run(&self, id: SegmentId, inputs: &[&QTensor]) -> Result<Vec<QTensor>> {
        anyhow::ensure!(id.0 < self.segments.len(), "segment id {} out of range", id.0);
        self.segments[id.0].execute(inputs)
    }
}
