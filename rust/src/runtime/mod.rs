//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt`) and
//! executes them on the CPU PJRT client. This is the "programmable
//! logic" of the reproduction: each artifact plays the role of one
//! FSM-sequenced stage group of FADEC's accelerator, compiled once at
//! startup (the analog of configuring the bitstream) and executed many
//! times per frame.
//!
//! Interchange is HLO *text* (not serialized protos): jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and DESIGN.md §9).

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::data::manifest::{Manifest, SegmentDesc};
use crate::quant::QTensor;
use crate::tensor::Tensor;

/// One compiled HW segment.
pub struct Segment {
    pub desc: SegmentDesc,
    exe: xla::PjRtLoadedExecutable,
}

impl Segment {
    /// Execute with int16 inputs in manifest order; returns the outputs
    /// as QTensors with manifest exponents.
    pub fn execute(&self, inputs: &[&QTensor]) -> Result<Vec<QTensor>> {
        anyhow::ensure!(
            inputs.len() == self.desc.inputs.len(),
            "segment {}: {} inputs given, {} expected",
            self.desc.name,
            inputs.len(),
            self.desc.inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (q, d) in inputs.iter().zip(&self.desc.inputs) {
            anyhow::ensure!(
                q.t.shape() == d.shape.as_slice(),
                "segment {}: input '{}' shape {:?} != manifest {:?}",
                self.desc.name,
                d.name,
                q.t.shape(),
                d.shape
            );
            anyhow::ensure!(
                q.exp == d.exp,
                "segment {}: input '{}' exponent {} != manifest {}",
                self.desc.name,
                d.name,
                q.exp,
                d.exp
            );
            literals.push(literal_from_i16(&q.t, &d.shape));
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple result
        let parts = tuple.to_tuple()?;
        anyhow::ensure!(
            parts.len() == self.desc.outputs.len(),
            "segment {}: {} outputs returned, {} in manifest",
            self.desc.name,
            parts.len(),
            self.desc.outputs.len()
        );
        let mut out = Vec::with_capacity(parts.len());
        for (lit, d) in parts.into_iter().zip(&self.desc.outputs) {
            let v: Vec<i16> = lit.to_vec::<i16>()?;
            anyhow::ensure!(
                v.len() == d.numel(),
                "segment {}: output '{}' size {} != {:?}",
                self.desc.name,
                d.name,
                v.len(),
                d.shape
            );
            out.push(QTensor {
                t: Tensor::from_vec(&d.shape, v),
                exp: d.exp,
            });
        }
        Ok(out)
    }
}

fn literal_from_i16(t: &Tensor<i16>, shape: &[usize]) -> xla::Literal {
    let dims: Vec<usize> = shape.to_vec();
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.len() * 2)
    };
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S16,
        &dims,
        bytes,
    )
    .expect("literal creation")
}

/// The PL analog: a PJRT CPU client plus every compiled segment.
pub struct HwRuntime {
    pub client: xla::PjRtClient,
    pub segments: HashMap<String, Segment>,
    pub compile_seconds: f64,
}

impl HwRuntime {
    /// Load + compile every artifact in the manifest ("flash the
    /// bitstream"). Compilation happens once; execution is reused across
    /// frames, matching the paper's deployment model.
    pub fn load(artifacts_dir: &Path, manifest: &Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let t0 = Instant::now();
        let mut segments = HashMap::new();
        for desc in &manifest.segments {
            let path = artifacts_dir.join(&desc.hlo);
            if !path.is_file() {
                bail!(
                    "artifact {} missing — run `make artifacts`",
                    path.display()
                );
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", desc.name))?;
            segments.insert(
                desc.name.clone(),
                Segment { desc: desc.clone(), exe },
            );
        }
        Ok(HwRuntime {
            client,
            segments,
            compile_seconds: t0.elapsed().as_secs_f64(),
        })
    }

    pub fn segment(&self, name: &str) -> Result<&Segment> {
        self.segments
            .get(name)
            .with_context(|| format!("segment '{name}' not loaded"))
    }

    /// Execute a segment by name.
    pub fn run(&self, name: &str, inputs: &[&QTensor]) -> Result<Vec<QTensor>> {
        self.segment(name)?.execute(inputs)
    }
}
