//! Pure-software reference backend: serves every manifest segment with
//! the bit-exact Rust integer mirrors (`model::quant_net`) instead of
//! PJRT-compiled artifacts.
//!
//! Two uses:
//! * **Artifact-free operation** — paired with [`Manifest::synthetic`]
//!   and [`QuantParams::synthetic`], the whole Backend/Session/Server
//!   stack runs and is testable from a clean checkout (no `make
//!   artifacts`, no `libxla_extension`).
//! * **Cross-checking** — given the *real* manifest + qparams it computes
//!   exactly what the PJRT artifacts compute (the golden tests pin both
//!   against the same python traces).
//!
//! Segment names are classified once at construction; the hot `run` path
//! is an index into a flat table (same contract as `HwRuntime`).
//!
//! # Async submissions
//!
//! `RefBackend` implements the real (non-eager) side of the submit/await
//! contract (`runtime` module docs): a dedicated **backend worker**
//! thread — the analog of the PL command processor — drains a FIFO job
//! queue. [`HwBackend::submit_batch`] validates the inputs and moves the
//! caller's owned handles straight into the job — tensor payloads are
//! Arc-backed, so enqueueing copies **zero payload bytes** (the queue
//! carries descriptors, not pixels; the PR-4 implementation deep-copied
//! every batch here). The worker executes jobs strictly in submission
//! order through the very same segment mirrors as the blocking path, so
//! submitted results are bit-identical to `run_batch` by construction,
//! and it drops a job's input handles *before* delivering its
//! completion — after `wait` returns, the inputs of that submission (and
//! of every earlier one) have provably retired. The worker shares the
//! model (and its conv-thread arena) through an `Arc`, so the packed tap
//! lists and scratch freelists are the same ones the blocking path uses.
//! [`RefBackend::submit_payload_bytes`] counts the input bytes that
//! crossed the queue (what the old copying path would have cloned) for
//! the serve bench's copy accounting.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::data::manifest::{Manifest, SegmentDesc};
use crate::model::weights::QuantParams;
use crate::model::QuantModel;
use crate::quant::QTensor;

use super::{check_inputs, HwBackend, HwCompletion, SegmentId, SubmitHandle};

/// What a manifest segment computes (parsed from its name once).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SegKind {
    FeFs,
    Cve,
    ClGates,
    ClState,
    ClOut,
    CvdEntry(usize),
    CvdMid(usize, usize),
    CvdHead(usize),
}

fn classify(name: &str) -> Result<SegKind> {
    Ok(match name {
        "fe_fs" => SegKind::FeFs,
        "cve" => SegKind::Cve,
        "cl_gates" => SegKind::ClGates,
        "cl_state" => SegKind::ClState,
        "cl_out" => SegKind::ClOut,
        other => {
            let rest = other
                .strip_prefix("cvd_b")
                .with_context(|| format!("unknown segment '{other}'"))?;
            let (b_str, tail) = rest
                .split_once('_')
                .with_context(|| format!("malformed segment '{other}'"))?;
            let b: usize = b_str
                .parse()
                .with_context(|| format!("bad block index in '{other}'"))?;
            if tail == "entry" {
                SegKind::CvdEntry(b)
            } else if tail == "head" {
                SegKind::CvdHead(b)
            } else if let Some(i) = tail.strip_prefix("mid") {
                SegKind::CvdMid(
                    b,
                    i.parse()
                        .with_context(|| format!("bad mid index in '{other}'"))?,
                )
            } else {
                bail!("unknown segment '{other}'");
            }
        }
    })
}

/// Segment-serving core, shared between the caller-facing backend and
/// its submission worker thread.
struct RefInner {
    qp: Arc<QuantParams>,
    model: QuantModel,
    manifest: Manifest,
    kinds: Vec<SegKind>,
    index: HashMap<String, usize>,
    /// Submitted-but-not-yet-completed jobs on the worker queue — the
    /// occupancy signal behind `HwBackend::queue_depth`. Incremented
    /// *before* a job crosses the queue and decremented by the worker
    /// just before delivering its completion, so a sampled value never
    /// underflows and a returned `wait` implies the job is uncounted.
    inflight: AtomicUsize,
}

/// One queued submission: the segment, the batch's *owned input handles*
/// (moved from the submitter — no payload copies), and the channel its
/// [`HwCompletion`] is delivered on.
struct HwJob {
    id: SegmentId,
    batch: Vec<Vec<QTensor>>,
    resp: Sender<HwCompletion>,
}

/// The software PL: quantized Rust mirrors behind the backend contract.
pub struct RefBackend {
    inner: Arc<RefInner>,
    /// Submission queue to the backend worker (the PL command queue):
    /// jobs execute strictly in submission order. `None` after shutdown.
    queue: Mutex<Option<Sender<HwJob>>>,
    worker: Mutex<Option<JoinHandle<()>>>,
    /// Input payload bytes handed to `submit_batch` since construction —
    /// exactly the bytes the PR-4 copying submit path deep-copied per
    /// job, now moved as handles. The serve bench reports this as the
    /// before/after copy accounting.
    submit_payload_bytes: AtomicU64,
}

impl RefBackend {
    /// Serve `manifest`'s segments with the integer mirrors parametrised
    /// by `qp` (real calibrated parameters or synthetic ones).
    pub fn new(qp: Arc<QuantParams>, manifest: Manifest) -> Result<Self> {
        let kinds = manifest
            .segments
            .iter()
            .map(|d| classify(&d.name))
            .collect::<Result<Vec<_>>>()?;
        let index = manifest
            .segments
            .iter()
            .enumerate()
            .map(|(i, d)| (d.name.clone(), i))
            .collect();
        let model = QuantModel::new(Arc::clone(&qp));
        let inner = Arc::new(RefInner {
            qp,
            model,
            manifest,
            kinds,
            index,
            inflight: AtomicUsize::new(0),
        });
        let (tx, rx) = channel::<HwJob>();
        let exec = Arc::clone(&inner);
        let worker = std::thread::Builder::new()
            .name("fadec-hw-queue".into())
            .spawn(move || {
                while let Ok(job) = rx.recv() {
                    let HwJob { id, batch, resp } = job;
                    let t0 = Instant::now();
                    // catch panics as well as Errs: one poisoned job must
                    // not kill the worker (which would wedge the FIFO for
                    // every later submission and leak `inflight` forever)
                    // — the fault/retry contract's worker-survival rule
                    let outs = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(|| {
                            let refs: Vec<Vec<&QTensor>> = batch
                                .iter()
                                .map(|inputs| inputs.iter().collect())
                                .collect();
                            exec.exec_batch(id, &refs)
                        }),
                    )
                    .unwrap_or_else(|payload| {
                        let msg = payload
                            .downcast_ref::<&str>()
                            .map(|s| (*s).to_string())
                            .or_else(|| {
                                payload.downcast_ref::<String>().cloned()
                            })
                            .unwrap_or_else(|| {
                                "non-string panic payload".to_string()
                            });
                        Err(anyhow!("backend job panicked: {msg}"))
                    });
                    // retire the input handles *before* delivering the
                    // completion: once a submitter's wait returns, its
                    // inputs are guaranteed dropped (so e.g. a payload
                    // the caller kept a handle to is unique again)
                    drop(batch);
                    // retire the job from the occupancy count *before*
                    // its completion goes out: once a wait returns, the
                    // job is guaranteed no longer counted (and the count
                    // cannot underflow — every received job was counted
                    // before it crossed the queue)
                    exec.inflight.fetch_sub(1, Ordering::Relaxed);
                    // a dropped handle abandons its result; that's fine
                    let _ = resp.send(HwCompletion {
                        outs,
                        start: t0,
                        end: Instant::now(),
                    });
                }
            })
            .map_err(|e| anyhow!("spawning backend worker: {e}"))?;
        Ok(RefBackend {
            inner,
            queue: Mutex::new(Some(tx)),
            worker: Mutex::new(Some(worker)),
            submit_payload_bytes: AtomicU64::new(0),
        })
    }

    /// Fully self-contained backend: synthetic manifest + deterministic
    /// synthetic quantized parameters. This is what makes the whole
    /// pipeline runnable from a clean checkout with no `artifacts/`.
    pub fn synthetic(seed: u64) -> Self {
        let manifest = Manifest::synthetic();
        let qp = Arc::new(QuantParams::synthetic(&manifest, seed));
        Self::new(qp, manifest).expect("synthetic manifest is well-formed")
    }

    /// The quantized parameters this backend computes with.
    pub fn qp(&self) -> &Arc<QuantParams> {
        &self.inner.qp
    }

    /// Stripe every conv's output channels over `threads` scoped workers
    /// (the `PipelineOptions::conv_threads` knob). Results are
    /// bit-identical for every thread count — only the latency changes.
    pub fn with_conv_threads(self, threads: usize) -> Self {
        self.inner.model.set_conv_threads(threads);
        self
    }

    pub fn conv_threads(&self) -> usize {
        self.inner.model.conv_threads()
    }

    /// Input payload bytes that crossed the submit queue since
    /// construction. This is exactly what the old copying submit path
    /// deep-copied per job; the ownership-transferring path moves the
    /// same bytes as Arc handles, copying none of them (pinned by
    /// `rust/tests/alloc_free.rs` under `--features count-allocs`).
    pub fn submit_payload_bytes(&self) -> u64 {
        self.submit_payload_bytes.load(Ordering::Relaxed)
    }
}

impl Drop for RefBackend {
    fn drop(&mut self) {
        // close the queue, then join the worker (mirrors ExternLink)
        drop(self.queue.lock().unwrap().take());
        if let Some(w) = self.worker.lock().unwrap().take() {
            let _ = w.join();
        }
    }
}

impl RefInner {
    /// Blocking execution of one segment (the body of `HwBackend::run`;
    /// also what the worker thread runs for width-1 jobs).
    fn exec(&self, id: SegmentId, inputs: &[&QTensor]) -> Result<Vec<QTensor>> {
        let desc = self
            .manifest
            .segments
            .get(id.0)
            .with_context(|| format!("segment id {} out of range", id.0))?;
        check_inputs(desc, inputs)?;
        let out = match self.kinds[id.0] {
            SegKind::FeFs => self.model.seg_fe_fs(inputs[0]),
            SegKind::Cve => self.model.seg_cve(inputs[0], &inputs[1..]),
            SegKind::ClGates => {
                vec![self.model.seg_cl_gates(inputs[0], inputs[1])]
            }
            SegKind::ClState => {
                let (c_new, o_gate) =
                    self.model.seg_cl_state(inputs[0], inputs[1]);
                vec![c_new, o_gate]
            }
            SegKind::ClOut => vec![self.model.seg_cl_out(inputs[0], inputs[1])],
            SegKind::CvdEntry(b) => vec![self.model.seg_cvd_entry(b, inputs)],
            SegKind::CvdMid(b, i) => {
                vec![self.model.seg_cvd_mid(b, i, inputs[0])]
            }
            SegKind::CvdHead(b) => vec![self.model.seg_cvd_head(b, inputs[0])],
        };
        check_outputs(desc, &out)?;
        Ok(out)
    }

    /// Real batched execution: conv-bearing segments run every conv once
    /// over the whole batch through the batched model mirrors (shared
    /// `PackedConv` tap lists, one thread-scope per conv); conv-free
    /// segments (`cl_state`, `cl_out`) loop — they are pure elementwise
    /// glue with nothing to amortise. Each batch element is bit-identical
    /// to `exec` on that element alone.
    fn exec_batch(
        &self,
        id: SegmentId,
        batch: &[Vec<&QTensor>],
    ) -> Result<Vec<Vec<QTensor>>> {
        let desc = self
            .manifest
            .segments
            .get(id.0)
            .with_context(|| format!("segment id {} out of range", id.0))?;
        for inputs in batch {
            check_inputs(desc, inputs)?;
        }
        let outs: Vec<Vec<QTensor>> = match self.kinds[id.0] {
            SegKind::FeFs => {
                let imgs: Vec<&QTensor> =
                    batch.iter().map(|ins| ins[0]).collect();
                self.model.seg_fe_fs_batch(&imgs)
            }
            SegKind::Cve => self.model.seg_cve_batch(batch),
            SegKind::ClGates => self
                .model
                .seg_cl_gates_batch(batch)
                .into_iter()
                .map(|y| vec![y])
                .collect(),
            SegKind::ClState => batch
                .iter()
                .map(|ins| {
                    let (c_new, o_gate) =
                        self.model.seg_cl_state(ins[0], ins[1]);
                    vec![c_new, o_gate]
                })
                .collect(),
            SegKind::ClOut => batch
                .iter()
                .map(|ins| vec![self.model.seg_cl_out(ins[0], ins[1])])
                .collect(),
            SegKind::CvdEntry(b) => self
                .model
                .seg_cvd_entry_batch(b, batch)
                .into_iter()
                .map(|y| vec![y])
                .collect(),
            SegKind::CvdMid(b, i) => {
                let xs: Vec<&QTensor> = batch.iter().map(|ins| ins[0]).collect();
                self.model
                    .seg_cvd_mid_batch(b, i, &xs)
                    .into_iter()
                    .map(|y| vec![y])
                    .collect()
            }
            SegKind::CvdHead(b) => {
                let xs: Vec<&QTensor> = batch.iter().map(|ins| ins[0]).collect();
                self.model
                    .seg_cvd_head_batch(b, &xs)
                    .into_iter()
                    .map(|y| vec![y])
                    .collect()
            }
        };
        anyhow::ensure!(
            outs.len() == batch.len(),
            "segment {}: {} batch outputs for {} inputs",
            desc.name,
            outs.len(),
            batch.len()
        );
        for out in &outs {
            check_outputs(desc, out)?;
        }
        Ok(outs)
    }
}

impl HwBackend for RefBackend {
    fn kind(&self) -> &'static str {
        "ref"
    }

    fn set_conv_threads(&self, threads: usize) {
        self.inner.model.set_conv_threads(threads);
    }

    fn manifest(&self) -> &Manifest {
        &self.inner.manifest
    }

    fn resolve(&self, name: &str) -> Result<SegmentId> {
        self.inner
            .index
            .get(name)
            .map(|&i| SegmentId(i))
            .with_context(|| format!("segment '{name}' not in manifest"))
    }

    fn segment_desc(&self, id: SegmentId) -> &SegmentDesc {
        &self.inner.manifest.segments[id.0]
    }

    fn run(&self, id: SegmentId, inputs: &[&QTensor]) -> Result<Vec<QTensor>> {
        self.inner.exec(id, inputs)
    }

    fn run_batch(
        &self,
        id: SegmentId,
        batch: &[Vec<&QTensor>],
    ) -> Result<Vec<Vec<QTensor>>> {
        self.inner.exec_batch(id, batch)
    }

    /// Real async submission: validate the inputs (the DMA-descriptor
    /// check happens at enqueue time) and move the caller's handles into
    /// the job — **zero payload bytes copied or allocated**; the queue
    /// carries Arc handles the way a command queue carries DMA
    /// descriptors. The worker executes jobs strictly in submission
    /// order through `exec_batch`, so a submitted segment is
    /// bit-identical to the blocking `run_batch` path by construction —
    /// and it executes while the caller runs software stages, which is
    /// the overlap `StreamServer::run_pipelined` schedules around.
    fn submit_batch(
        &self,
        id: SegmentId,
        batch: Vec<Vec<QTensor>>,
    ) -> Result<SubmitHandle> {
        let desc = self
            .inner
            .manifest
            .segments
            .get(id.0)
            .with_context(|| format!("segment id {} out of range", id.0))?;
        let mut bytes = 0u64;
        for inputs in &batch {
            let refs: Vec<&QTensor> = inputs.iter().collect();
            check_inputs(desc, &refs)?;
            bytes += inputs
                .iter()
                .map(|q| (q.t.len() * std::mem::size_of::<i16>()) as u64)
                .sum::<u64>();
        }
        let (resp_tx, resp_rx) = channel();
        // count the job in-flight *before* it crosses the queue — the
        // worker decrements after delivering the completion, so a sampled
        // queue_depth never underflows; a failed enqueue undoes the add
        self.inner.inflight.fetch_add(1, Ordering::Relaxed);
        let sent = self
            .queue
            .lock()
            .unwrap()
            .as_ref()
            .context("backend worker shut down")
            .and_then(|q| {
                q.send(HwJob { id, batch, resp: resp_tx })
                    .map_err(|_| anyhow!("backend worker gone"))
            });
        if let Err(e) = sent {
            self.inner.inflight.fetch_sub(1, Ordering::Relaxed);
            return Err(e);
        }
        // counted only once the job actually crossed the queue (a failed
        // enqueue must not inflate the copy accounting)
        self.submit_payload_bytes.fetch_add(bytes, Ordering::Relaxed);
        Ok(SubmitHandle::queued(resp_rx))
    }

    /// Jobs submitted to the worker whose completions have not yet been
    /// delivered — the occupancy signal the shard router's placement and
    /// rebalancing read through `&dyn HwBackend`.
    fn queue_depth(&self) -> usize {
        self.inner.inflight.load(Ordering::Relaxed)
    }

    /// Trait-level view of [`RefBackend::submit_payload_bytes`] so
    /// per-shard queue traffic is reportable through `&dyn HwBackend`.
    fn submit_payload_bytes(&self) -> u64 {
        self.submit_payload_bytes.load(Ordering::Relaxed)
    }
}

/// Output shape/exponent validation shared by `run` and `run_batch`.
fn check_outputs(desc: &SegmentDesc, out: &[QTensor]) -> Result<()> {
    anyhow::ensure!(
        out.len() == desc.outputs.len(),
        "segment {}: {} outputs computed, {} in manifest",
        desc.name,
        out.len(),
        desc.outputs.len()
    );
    for (o, d) in out.iter().zip(&desc.outputs) {
        anyhow::ensure!(
            o.t.shape() == d.shape.as_slice(),
            "segment {}: output '{}' shape {:?} != manifest {:?}",
            desc.name,
            d.name,
            o.t.shape(),
            d.shape
        );
        anyhow::ensure!(
            o.exp == d.exp,
            "segment {}: output '{}' exponent {} != manifest {}",
            desc.name,
            d.name,
            o.exp,
            d.exp
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::quant::quantize_tensor;
    use crate::tensor::TensorF;
    use crate::util::Rng;

    fn random_image(seed: u64) -> TensorF {
        let mut rng = Rng::new(seed);
        let n = 3 * config::IMG_H * config::IMG_W;
        TensorF::from_vec(
            &[1, 3, config::IMG_H, config::IMG_W],
            (0..n).map(|_| rng.range_f32(-2.0, 2.0)).collect(),
        )
    }

    #[test]
    fn synthetic_backend_serves_all_19_segments() {
        let be = RefBackend::synthetic(7);
        assert_eq!(be.manifest().segments.len(), 19);
        assert_eq!(be.kind(), "ref");
        for seg in &be.manifest().segments {
            let id = be.resolve(&seg.name).unwrap();
            assert_eq!(be.segment_desc(id).name, seg.name);
        }
        assert!(be.resolve("nope").is_err());
    }

    #[test]
    fn fe_fs_runs_and_matches_manifest_shapes() {
        let be = RefBackend::synthetic(7);
        let img_q =
            quantize_tensor(&random_image(1), be.qp().aexp("image"));
        let id = be.resolve("fe_fs").unwrap();
        let outs = be.run(id, &[&img_q]).unwrap();
        assert_eq!(outs.len(), 5);
        for (o, d) in outs.iter().zip(&be.segment_desc(id).outputs) {
            assert_eq!(o.t.shape(), d.shape.as_slice());
            assert_eq!(o.exp, d.exp);
        }
    }

    #[test]
    fn run_rejects_wrong_shape_and_exponent() {
        let be = RefBackend::synthetic(7);
        let id = be.resolve("fe_fs").unwrap();
        let bad_shape = QTensor::zeros(&[1, 3, 8, 8], be.qp().aexp("image"));
        assert!(be.run(id, &[&bad_shape]).is_err());
        let bad_exp = QTensor::zeros(
            &[1, 3, config::IMG_H, config::IMG_W],
            be.qp().aexp("image") + 1,
        );
        assert!(be.run(id, &[&bad_exp]).is_err());
    }

    #[test]
    fn run_batch_matches_per_stream_runs_on_fe_fs() {
        let be = RefBackend::synthetic(7);
        let id = be.resolve("fe_fs").unwrap();
        let imgs: Vec<QTensor> = (0..3u64)
            .map(|i| quantize_tensor(&random_image(i + 10), be.qp().aexp("image")))
            .collect();
        let batch: Vec<Vec<&QTensor>> = imgs.iter().map(|q| vec![q]).collect();
        let batched = be.run_batch(id, &batch).unwrap();
        assert_eq!(batched.len(), 3);
        for (bi, ins) in batch.iter().enumerate() {
            let solo = be.run(id, ins).unwrap();
            assert_eq!(solo.len(), batched[bi].len());
            for (a, b) in solo.iter().zip(&batched[bi]) {
                assert_eq!(a.t.data(), b.t.data(), "stream {bi}");
                assert_eq!(a.exp, b.exp);
            }
        }
    }

    #[test]
    fn submitted_segments_match_blocking_run_batch() {
        let be = RefBackend::synthetic(7);
        let id = be.resolve("fe_fs").unwrap();
        let imgs: Vec<QTensor> = (0..2u64)
            .map(|i| quantize_tensor(&random_image(i + 50), be.qp().aexp("image")))
            .collect();
        let batch: Vec<Vec<&QTensor>> = imgs.iter().map(|q| vec![q]).collect();
        let blocking = be.run_batch(id, &batch).unwrap();
        // submission takes owned handles: O(1) clones of the same payloads
        let owned: Vec<Vec<QTensor>> =
            imgs.iter().map(|q| vec![q.clone()]).collect();
        let handle = be.submit_batch(id, owned).unwrap();
        let (outs, start, end) = handle.wait_batch_timed().unwrap();
        assert!(end >= start, "worker interval is ordered");
        assert_eq!(outs.len(), blocking.len());
        for (a, b) in outs.iter().zip(&blocking) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.t.data(), y.t.data());
                assert_eq!(x.exp, y.exp);
            }
        }
    }

    #[test]
    fn handles_may_be_waited_out_of_submission_order() {
        // execution is FIFO on the worker, but each handle owns its
        // completion channel, so waits can happen in any order
        let be = RefBackend::synthetic(7);
        let id = be.resolve("fe_fs").unwrap();
        let img_a = quantize_tensor(&random_image(60), be.qp().aexp("image"));
        let img_b = quantize_tensor(&random_image(61), be.qp().aexp("image"));
        let want_a = be.run(id, &[&img_a]).unwrap();
        let want_b = be.run(id, &[&img_b]).unwrap();
        let ha = be.submit(id, vec![img_a]).unwrap();
        let hb = be.submit(id, vec![img_b]).unwrap();
        let got_b = hb.wait().unwrap();
        let got_a = ha.wait().unwrap();
        for (x, y) in got_a.iter().zip(&want_a) {
            assert_eq!(x.t.data(), y.t.data());
        }
        for (x, y) in got_b.iter().zip(&want_b) {
            assert_eq!(x.t.data(), y.t.data());
        }
    }

    #[test]
    fn submit_rejects_bad_inputs_at_enqueue_time() {
        let be = RefBackend::synthetic(7);
        let id = be.resolve("fe_fs").unwrap();
        let bad = QTensor::zeros(&[1, 3, 8, 8], be.qp().aexp("image"));
        assert!(be.submit(id, vec![bad]).is_err());
    }

    #[test]
    fn submit_moves_handles_and_retires_them_after_wait() {
        // ownership-transferring submit: the job holds the very same
        // payload the caller quantized (no deep copy), and the worker
        // drops it before delivering the completion — so a handle the
        // caller kept becomes the unique owner again once wait returns
        let be = RefBackend::synthetic(7);
        let id = be.resolve("fe_fs").unwrap();
        let img = quantize_tensor(&random_image(90), be.qp().aexp("image"));
        let probe = img.clone();
        assert!(!probe.t.is_unique(), "probe aliases the submitted input");
        let bytes_before = be.submit_payload_bytes();
        let handle = be.submit(id, vec![img]).unwrap();
        let outs = handle.wait().unwrap();
        assert!(!outs.is_empty());
        assert!(
            probe.t.is_unique(),
            "after wait the submission's input handles have retired"
        );
        let moved = be.submit_payload_bytes() - bytes_before;
        assert_eq!(
            moved,
            (probe.t.len() * std::mem::size_of::<i16>()) as u64,
            "submit accounting covers exactly the input payload bytes"
        );
    }

    #[test]
    fn queue_depth_tracks_inflight_submissions() {
        let be = RefBackend::synthetic(7);
        assert_eq!(be.queue_depth(), 0);
        let id = be.resolve("fe_fs").unwrap();
        let img = quantize_tensor(&random_image(80), be.qp().aexp("image"));
        let handles: Vec<_> = (0..3)
            .map(|_| be.submit(id, vec![img.clone()]).unwrap())
            .collect();
        // sampled while the worker drains: never more than submitted,
        // never negative (usize), and back to 0 once all are waited
        assert!(be.queue_depth() <= 3);
        for h in handles {
            h.wait().unwrap();
        }
        assert_eq!(be.queue_depth(), 0);
        // the trait-level bytes accessor mirrors the inherent one
        let dyn_be: &dyn HwBackend = &be;
        assert_eq!(dyn_be.submit_payload_bytes(), be.submit_payload_bytes());
        assert!(be.submit_payload_bytes() > 0);
    }

    #[test]
    fn worker_survives_job_error_without_wedging_the_queue() {
        // a manifest whose cvd_b0_mid1 output exponent disagrees with
        // what the model computes: the submit-side input check passes,
        // the worker-side output check fails -> an Err completion that
        // must not poison the FIFO or leak the inflight counter
        let mut manifest = Manifest::synthetic();
        let qp = Arc::new(QuantParams::synthetic(&manifest, 7));
        let bad = manifest
            .segments
            .iter_mut()
            .find(|s| s.name == "cvd_b0_mid1")
            .unwrap();
        bad.outputs[0].exp += 1;
        let in_desc = bad.inputs[0].clone();
        let be = RefBackend::new(qp, manifest).unwrap();

        let bad_id = be.resolve("cvd_b0_mid1").unwrap();
        let x = QTensor::zeros(&in_desc.shape, in_desc.exp);
        let err = be
            .submit(bad_id, vec![x])
            .unwrap()
            .wait()
            .unwrap_err();
        assert!(format!("{err:#}").contains("exponent"), "{err:#}");
        // the queue keeps serving: an untouched segment still executes
        // bit-exactly and the occupancy counter returns to zero
        let fe = be.resolve("fe_fs").unwrap();
        let img = quantize_tensor(&random_image(3), be.qp().aexp("image"));
        let want = be.run(fe, &[&img]).unwrap();
        let got = be.submit(fe, vec![img]).unwrap().wait().unwrap();
        assert_eq!(got[0].t.data(), want[0].t.data());
        assert_eq!(be.queue_depth(), 0, "failed job retired from the count");
    }

    #[test]
    fn worker_survives_job_panic() {
        // a manifest that declares fe_fs's input as 1-D: the submit-side
        // check passes a matching 1-D tensor, but the model's first conv
        // asserts 4-D and panics *on the worker thread*. The worker must
        // convert the panic to an Err completion and keep draining jobs
        // (before PR 7 the panic killed the worker: every later wait
        // hung on "backend worker dropped" and queue_depth leaked)
        let mut manifest = Manifest::synthetic();
        let qp = Arc::new(QuantParams::synthetic(&manifest, 7));
        let seg = manifest
            .segments
            .iter_mut()
            .find(|s| s.name == "fe_fs")
            .unwrap();
        seg.inputs[0].shape = vec![48];
        let in_exp = seg.inputs[0].exp;
        let be = RefBackend::new(qp, manifest).unwrap();

        let fe = be.resolve("fe_fs").unwrap();
        let bad = QTensor::zeros(&[48], in_exp);
        let err = be.submit(fe, vec![bad]).unwrap().wait().unwrap_err();
        assert!(format!("{err:#}").contains("panicked"), "{err:#}");
        // the worker is alive: a conv-free segment still serves, and the
        // panicked job neither wedged the FIFO nor leaked queue_depth
        let id = be.resolve("cl_state").unwrap();
        let d = be.segment_desc(id).clone();
        let gates = QTensor::zeros(&d.inputs[0].shape, d.inputs[0].exp);
        let c = QTensor::zeros(&d.inputs[1].shape, d.inputs[1].exp);
        let outs = be
            .submit(id, vec![gates, c])
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(outs.len(), 2);
        assert_eq!(be.queue_depth(), 0);
    }

    /// Delegates `run`/`run_batch` but keeps the trait's default
    /// `submit*`, exercising the eager fallback any third-party backend
    /// gets for free.
    struct EagerWrap(RefBackend);

    impl HwBackend for EagerWrap {
        fn kind(&self) -> &'static str {
            "eager-test"
        }
        fn manifest(&self) -> &Manifest {
            self.0.manifest()
        }
        fn resolve(&self, name: &str) -> Result<SegmentId> {
            self.0.resolve(name)
        }
        fn segment_desc(&self, id: SegmentId) -> &SegmentDesc {
            self.0.segment_desc(id)
        }
        fn run(&self, id: SegmentId, inputs: &[&QTensor]) -> Result<Vec<QTensor>> {
            self.0.run(id, inputs)
        }
    }

    #[test]
    fn default_eager_submit_matches_run() {
        let be = EagerWrap(RefBackend::synthetic(7));
        let id = be.resolve("fe_fs").unwrap();
        let img = quantize_tensor(&random_image(70), be.0.qp().aexp("image"));
        let want = be.run(id, &[&img]).unwrap();
        let got = be.submit(id, vec![img]).unwrap().wait().unwrap();
        assert_eq!(want.len(), got.len());
        for (x, y) in got.iter().zip(&want) {
            assert_eq!(x.t.data(), y.t.data());
            assert_eq!(x.exp, y.exp);
        }
    }

    #[test]
    fn same_seed_is_bit_deterministic() {
        let a = RefBackend::synthetic(3);
        let b = RefBackend::synthetic(3);
        let img_q = quantize_tensor(&random_image(2), a.qp().aexp("image"));
        let ia = a.resolve("fe_fs").unwrap();
        let ib = b.resolve("fe_fs").unwrap();
        let oa = a.run(ia, &[&img_q]).unwrap();
        let ob = b.run(ib, &[&img_q]).unwrap();
        for (x, y) in oa.iter().zip(&ob) {
            assert_eq!(x.t.data(), y.t.data());
        }
    }
}
