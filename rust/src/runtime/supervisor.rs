//! Worker-process lifecycle — the [`Supervisor`] owns one
//! [`WorkerProcess`](super::ipc::WorkerProcess) and keeps it (or its
//! replacement) serving: it detects crashes (pipe EOF — the reader
//! thread poisons the connection), hangs (heartbeats stale beyond the
//! grace period, or the oldest in-flight request older than the
//! per-wait deadline — both answered with SIGKILL, since a wedged
//! child cannot be reasoned with), and restarts the child with
//! exponential backoff under a bounded budget. When the budget is
//! exhausted the supervisor surfaces [`BackendDown`], the tagged error
//! `ShardRouter`'s checkpoint-failover path treats as a dead shard —
//! containment, not cascade.
//!
//! Restarts are safe precisely because the worker is stateless between
//! rounds: it re-materializes `RefBackend::synthetic(seed)` from the
//! handshake, the parent re-verifies the manifest/parameter
//! fingerprints, and every in-flight request failed by the crash is
//! replayed by the coordinator's retry/failover machinery from
//! checkpointed session state — so the served suffix is bit-exact, per
//! the sessions-mutate-only-at-Commit invariant.

use std::fmt;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::data::tlv::TlvFile;
use crate::metrics::SupervisorStats;

use super::ipc::{worker_exe, WorkerProcess};
use super::HwCompletion;

/// Tagged terminal error: the worker is gone and the restart budget is
/// spent. `ShardRouter` routes this into checkpoint failover; callers
/// can test for it with [`is_backend_down`].
#[derive(Debug)]
pub struct BackendDown(pub String);

impl fmt::Display for BackendDown {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "backend down: {}", self.0)
    }
}

impl std::error::Error for BackendDown {}

/// Whether `err`'s chain contains a [`BackendDown`] (restart budget
/// exhausted — the shard is dead, not merely faulting).
pub fn is_backend_down(err: &anyhow::Error) -> bool {
    err.chain().any(|c| c.downcast_ref::<BackendDown>().is_some())
}

/// Supervision policy. A zero `heartbeat_grace` / `wait_deadline`
/// disables that detector; a zero `heartbeat_interval` stops the
/// worker from beating at all (crash detection via EOF still works —
/// it needs no timer).
#[derive(Clone, Debug)]
pub struct SupervisorOptions {
    /// Seed for the worker's synthetic manifest/parameters (must match
    /// the parent's, enforced by the handshake fingerprint check).
    pub seed: u64,
    /// Initial conv worker threads inside the child (0 = its default).
    pub conv_threads: usize,
    /// Period of the worker's heartbeat frames.
    pub heartbeat_interval: Duration,
    /// Heartbeat staleness beyond which the worker is declared frozen
    /// and killed (counted in `SupervisorStats::heartbeat_misses`).
    pub heartbeat_grace: Duration,
    /// Age of the oldest unanswered request beyond which the worker is
    /// declared stalled and killed (counted in `deadline_expiries`).
    /// Catches serve-loop hangs that heartbeats — a separate thread —
    /// cannot see.
    pub wait_deadline: Duration,
    /// Restarts allowed after the initial spawn before the supervisor
    /// gives up with [`BackendDown`].
    pub max_restarts: usize,
    /// Base of the exponential restart backoff (doubled per attempt).
    pub restart_backoff: Duration,
    /// Worker binary override; default is [`worker_exe`] discovery.
    pub worker_exe: Option<PathBuf>,
}

impl Default for SupervisorOptions {
    fn default() -> Self {
        SupervisorOptions {
            seed: 0,
            conv_threads: 0,
            heartbeat_interval: Duration::from_millis(25),
            heartbeat_grace: Duration::from_millis(500),
            wait_deadline: Duration::from_secs(5),
            max_restarts: 2,
            restart_backoff: Duration::from_millis(50),
            worker_exe: None,
        }
    }
}

impl SupervisorOptions {
    /// Default policy over a specific synthetic seed.
    pub fn for_seed(seed: u64) -> Self {
        SupervisorOptions { seed, ..Self::default() }
    }
}

struct SupCore {
    opts: SupervisorOptions,
    exe: PathBuf,
    manifest_fp: u64,
    qp_fp: u64,
    /// `None` only between a detected death and the next restart.
    worker: Mutex<Option<WorkerProcess>>,
    stats: Mutex<SupervisorStats>,
    /// When the current outage began (for `downtime_seconds`).
    down_at: Mutex<Option<Instant>>,
    restarts_used: AtomicUsize,
    conv_threads: AtomicUsize,
    shutdown: AtomicBool,
}

impl SupCore {
    fn spawn_worker(&self) -> Result<WorkerProcess> {
        let w = WorkerProcess::spawn(
            &self.exe,
            self.opts.seed,
            self.conv_threads.load(Ordering::Relaxed),
            self.opts.heartbeat_interval,
        )?;
        // a fingerprint mismatch is deterministic (version-skewed or
        // corrupt worker binary): fail hard, retrying cannot help
        if w.manifest_fp() != self.manifest_fp || w.qp_fp() != self.qp_fp {
            bail!(
                "worker fingerprints (manifest {:#x}, qp {:#x}) do not \
                 match the parent catalogue ({:#x}, {:#x}) — \
                 parent/worker build or seed skew",
                w.manifest_fp(),
                w.qp_fp(),
                self.manifest_fp,
                self.qp_fp
            );
        }
        Ok(w)
    }

    fn note_down(&self) {
        let mut down = self.down_at.lock().expect("down_at poisoned");
        if down.is_none() {
            *down = Some(Instant::now());
        }
    }

    /// Guarantee a live worker under the `worker` lock, restarting
    /// (with backoff) if the current one died. Errors with
    /// [`BackendDown`] once the restart budget is spent.
    fn ensure_live<'a>(
        &self,
        slot: &'a mut Option<WorkerProcess>,
    ) -> Result<&'a WorkerProcess> {
        if slot.as_ref().is_some_and(|w| w.alive()) {
            return Ok(slot.as_ref().expect("checked live"));
        }
        self.note_down();
        // reap the corpse before replacing it (Drop kills + waits)
        *slot = None;
        loop {
            let used = self.restarts_used.load(Ordering::Relaxed);
            if used >= self.opts.max_restarts {
                return Err(anyhow::Error::new(BackendDown(format!(
                    "worker process restart budget ({}) exhausted",
                    self.opts.max_restarts
                ))));
            }
            self.restarts_used.fetch_add(1, Ordering::Relaxed);
            thread::sleep(
                self.opts
                    .restart_backoff
                    .saturating_mul(1u32 << used.min(16) as u32),
            );
            match self.spawn_worker() {
                Ok(w) => {
                    let mut stats = self.stats.lock().expect("stats");
                    stats.restarts += 1;
                    if let Some(t0) =
                        self.down_at.lock().expect("down_at poisoned").take()
                    {
                        stats.downtime_seconds += t0.elapsed().as_secs_f64();
                    }
                    *slot = Some(w);
                    return Ok(slot.as_ref().expect("just installed"));
                }
                Err(e) => {
                    // transient spawn failure: burn an attempt and try
                    // again, unless that was the last one
                    if self.restarts_used.load(Ordering::Relaxed)
                        >= self.opts.max_restarts
                    {
                        return Err(e.context(
                            "worker restart failed and budget is exhausted",
                        ));
                    }
                }
            }
        }
    }
}

/// Supervised handle to the worker process behind an
/// [`IpcBackend`](super::ipc::IpcBackend). All request traffic funnels
/// through [`Supervisor::submit`], which transparently restarts a dead
/// worker (within budget) before forwarding; a monitor thread enforces
/// the heartbeat-grace and wait-deadline detectors by killing the
/// child so the crash path — EOF, failed pendings, retry, restart —
/// handles both hang flavors identically.
pub struct Supervisor {
    core: Arc<SupCore>,
    monitor: Option<JoinHandle<()>>,
}

impl Supervisor {
    /// Spawn the first worker (not counted against the restart
    /// budget), verify its fingerprints against the parent catalogue,
    /// and start the liveness monitor.
    pub fn start(
        manifest_fp: u64,
        qp_fp: u64,
        opts: SupervisorOptions,
    ) -> Result<Supervisor> {
        let exe = match &opts.worker_exe {
            Some(p) => p.clone(),
            None => worker_exe()?,
        };
        let conv_threads = AtomicUsize::new(opts.conv_threads);
        let core = Arc::new(SupCore {
            opts,
            exe,
            manifest_fp,
            qp_fp,
            worker: Mutex::new(None),
            stats: Mutex::new(SupervisorStats::default()),
            down_at: Mutex::new(None),
            restarts_used: AtomicUsize::new(0),
            conv_threads,
            shutdown: AtomicBool::new(false),
        });
        let first = core.spawn_worker().context("starting worker process")?;
        *core.worker.lock().expect("worker poisoned") = Some(first);
        let monitor = {
            let core = Arc::clone(&core);
            thread::Builder::new()
                .name("fadec-supervisor".into())
                .spawn(move || monitor_loop(&core))
                .context("spawning supervisor monitor")?
        };
        Ok(Supervisor { core, monitor: Some(monitor) })
    }

    /// Forward a reply-bearing request to a live worker (restarting
    /// one within budget if necessary). The receiver completes when
    /// the reader matches the reply — or fails fast if the worker dies
    /// first.
    pub fn submit(&self, frame: &TlvFile) -> Result<Receiver<HwCompletion>> {
        let mut slot = self.core.worker.lock().expect("worker poisoned");
        let w = self.core.ensure_live(&mut slot)?;
        w.send_expecting_reply(frame)
    }

    /// Forward a fire-and-forget frame to the *current* worker only —
    /// no restart (injecting a fault into a dead worker is
    /// meaningless, and conv-thread hints re-apply at respawn anyway).
    pub fn send_oneway(&self, frame: &TlvFile) -> Result<()> {
        let slot = self.core.worker.lock().expect("worker poisoned");
        match slot.as_ref() {
            Some(w) if w.alive() => w.send_oneway(frame),
            _ => bail!("worker process is down"),
        }
    }

    /// Crash injector: SIGKILL the current worker. The reader thread
    /// notices the EOF, fails the pendings, and the next `submit`
    /// restarts within budget.
    pub fn kill_worker(&self) {
        if let Some(w) =
            self.core.worker.lock().expect("worker poisoned").as_ref()
        {
            w.kill();
            self.core.note_down();
        }
    }

    /// In-flight requests awaiting replies (the backend's queue-depth
    /// signal).
    pub fn queue_depth(&self) -> usize {
        self.core
            .worker
            .lock()
            .expect("worker poisoned")
            .as_ref()
            .map_or(0, |w| w.pending_len())
    }

    /// Remember the conv-thread count for this and every future worker
    /// (the live hint itself is sent by the backend).
    pub fn set_conv_threads(&self, threads: usize) {
        self.core.conv_threads.store(threads, Ordering::Relaxed);
    }

    /// Snapshot of the supervision counters. `failover_replays` stays
    /// zero here — the router, which owns failover, fills it in.
    pub fn stats(&self) -> SupervisorStats {
        self.core.stats.lock().expect("stats").clone()
    }

    /// Restarts still available before [`BackendDown`].
    pub fn restarts_left(&self) -> usize {
        self.core
            .opts
            .max_restarts
            .saturating_sub(self.core.restarts_used.load(Ordering::Relaxed))
    }
}

impl Drop for Supervisor {
    fn drop(&mut self) {
        self.core.shutdown.store(true, Ordering::Release);
        if let Some(h) = self.monitor.take() {
            let _ = h.join();
        }
        // dropping the worker sends shutdown, closes stdin, reaps
        *self.core.worker.lock().expect("worker poisoned") = None;
    }
}

fn monitor_loop(core: &SupCore) {
    let tick = Duration::from_millis(5);
    while !core.shutdown.load(Ordering::Acquire) {
        thread::sleep(tick);
        let slot = core.worker.lock().expect("worker poisoned");
        let Some(w) = slot.as_ref() else { continue };
        if !w.alive() {
            continue; // already detected (crash or a prior kill)
        }
        let grace = core.opts.heartbeat_grace;
        let deadline = core.opts.wait_deadline;
        if !grace.is_zero()
            && !core.opts.heartbeat_interval.is_zero()
            && w.last_beat_age() > grace
        {
            // frozen: not even the heartbeat thread is scheduling.
            // kill() flips `alive` first, so this counts exactly once
            core.stats.lock().expect("stats").heartbeat_misses += 1;
            w.kill();
            drop(slot);
            core.note_down();
        } else if !deadline.is_zero()
            && w.oldest_pending_age().is_some_and(|age| age > deadline)
        {
            // stalled: heartbeats flow but the serve loop is wedged —
            // the oldest request has outlived the per-wait deadline
            core.stats.lock().expect("stats").deadline_expiries += 1;
            w.kill();
            drop(slot);
            core.note_down();
        }
    }
}
