//! Minimal dense tensor (row-major, Arc-backed copy-on-write) used by the
//! software operators and the CPU-only baselines.
//!
//! The request path manipulates small NCHW maps (at most 64x32x48), so a
//! contiguous row-major container is both sufficient and cache-friendly.
//! No views/strides: the paper's software side also works on packed
//! buffers in CMA memory.
//!
//! # The zero-copy data plane (PR 5)
//!
//! The payload is an `Arc<Vec<T>>`, so a tensor value is a cheap *handle*:
//!
//! * `clone()` is O(1) — it bumps the refcount and copies only the small
//!   shape vector. Every place a tensor is merely read (keyframe buffer
//!   entries, submit-queue inputs, chain taps, the session's previous
//!   depth) shares one payload instead of deep-copying it.
//! * Mutation goes through [`Tensor::data_mut`], which is
//!   `Arc::make_mut`: a no-op on a uniquely-owned payload, a one-time
//!   copy-on-write when the payload is shared. All `_into`/arena ops
//!   write into freshly checked-out (unique) buffers, so the hot loops
//!   never pay the CoW copy; correctness never depends on uniqueness —
//!   a mutation can only ever diverge the mutated handle.
//! * Ownership can be recovered: [`Tensor::try_unique_data`] returns the
//!   backing `Vec` (capacity intact) only when no other handle aliases
//!   it — the gate `ops::Arena` recycling stands behind, so a parked
//!   buffer is never resurrected under a live handle.

use std::fmt;
use std::sync::Arc;

/// Dense row-major tensor over a shared copy-on-write payload.
#[derive(Clone, PartialEq)]
pub struct Tensor<T> {
    shape: Vec<usize>,
    data: Arc<Vec<T>>,
}

pub type TensorF = Tensor<f32>;
pub type TensorI16 = Tensor<i16>;
pub type TensorI32 = Tensor<i32>;
pub type TensorI8 = Tensor<i8>;

impl<T: Copy + Default> Tensor<T> {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: Arc::new(vec![T::default(); n]) }
    }

    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data: Arc::new(data) }
    }

    pub fn full(shape: &[usize], v: T) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: Arc::new(vec![v; n]) }
    }

    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn data(&self) -> &[T] {
        &self.data
    }

    /// Mutable payload access — copy-on-write: free when this handle is
    /// the unique owner, a one-time payload copy when it is shared (the
    /// other handles keep the old bytes).
    #[inline]
    pub fn data_mut(&mut self) -> &mut [T] {
        Arc::make_mut(&mut self.data)
    }

    /// The backing `Vec`, cloning it only if other handles still share
    /// the payload. Prefer [`Tensor::try_unique_data`] on recycling
    /// paths, where a hidden clone would defeat the point.
    pub fn into_data(self) -> Vec<T> {
        Arc::try_unwrap(self.data).unwrap_or_else(|shared| (*shared).clone())
    }

    /// The backing `Vec` (capacity intact) iff this handle uniquely owns
    /// the payload; `None` when another handle still aliases it. This is
    /// the gate behind `Arena::recycle_*`: an aliased payload is dropped
    /// from the handle, never parked for reuse.
    pub fn try_unique_data(self) -> Option<Vec<T>> {
        Arc::try_unwrap(self.data).ok()
    }

    /// Whether this handle is the payload's only owner (observability
    /// for the CoW property tests).
    pub fn is_unique(&self) -> bool {
        Arc::strong_count(&self.data) == 1
    }

    /// Whether two handles alias the same payload allocation.
    pub fn shares_payload_with(&self, other: &Tensor<T>) -> bool {
        Arc::ptr_eq(&self.data, &other.data)
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    // --- NCHW helpers (the only layout used on the request path) ---------

    /// (N, C, H, W) of a 4-D tensor.
    #[inline]
    pub fn nchw(&self) -> (usize, usize, usize, usize) {
        assert_eq!(self.shape.len(), 4, "expected 4-D, got {:?}", self.shape);
        (self.shape[0], self.shape[1], self.shape[2], self.shape[3])
    }

    #[inline]
    pub fn at4(&self, n: usize, c: usize, h: usize, w: usize) -> T {
        let (_, cc, hh, ww) = self.nchw();
        self.data[((n * cc + c) * hh + h) * ww + w]
    }

    #[inline]
    pub fn set4(&mut self, n: usize, c: usize, h: usize, w: usize, v: T) {
        let (_, cc, hh, ww) = self.nchw();
        let idx = ((n * cc + c) * hh + h) * ww + w;
        self.data_mut()[idx] = v;
    }

    /// Contiguous channel plane (h*w slice) of batch 0.
    #[inline]
    pub fn plane(&self, c: usize) -> &[T] {
        let (_, cc, hh, ww) = self.nchw();
        assert!(c < cc);
        &self.data[c * hh * ww..(c + 1) * hh * ww]
    }

    #[inline]
    pub fn plane_mut(&mut self, c: usize) -> &mut [T] {
        let (_, cc, hh, ww) = self.nchw();
        assert!(c < cc);
        &mut self.data_mut()[c * hh * ww..(c + 1) * hh * ww]
    }

    /// Concatenate along the channel axis (dim 1), batch 1 assumed.
    pub fn concat_channels(parts: &[&Tensor<T>]) -> Self {
        assert!(!parts.is_empty());
        let (_, _, h, w) = parts[0].nchw();
        let c_total: usize = parts.iter().map(|p| p.nchw().1).sum();
        let mut out = Vec::with_capacity(c_total * h * w);
        for p in parts {
            let (_, _, ph, pw) = p.nchw();
            assert_eq!((ph, pw), (h, w), "spatial mismatch in concat");
            out.extend_from_slice(p.data());
        }
        Tensor::from_vec(&[1, c_total, h, w], out)
    }

    /// Channel slice [c0, c1) (dim 1), batch 1 assumed.
    pub fn slice_channels(&self, c0: usize, c1: usize) -> Self {
        let (_, c, h, w) = self.nchw();
        assert!(c0 < c1 && c1 <= c);
        let data = self.data[c0 * h * w..c1 * h * w].to_vec();
        Tensor::from_vec(&[1, c1 - c0, h, w], data)
    }
}

impl TensorF {
    pub fn map(&self, f: impl Fn(f32) -> f32) -> TensorF {
        Tensor {
            shape: self.shape.clone(),
            data: Arc::new(self.data.iter().map(|&x| f(x)).collect()),
        }
    }

    pub fn add(&self, other: &TensorF) -> TensorF {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: Arc::new(
                self.data
                    .iter()
                    .zip(other.data.iter())
                    .map(|(a, b)| a + b)
                    .collect(),
            ),
        }
    }

    /// In-place elementwise add — the allocation-free twin of
    /// [`TensorF::add`] (IEEE addition is commutative, so `a.add_assign(b)`
    /// is bit-identical to `b.add(a)` too). On a shared handle this pays
    /// one CoW copy first; hot paths operate on unique buffers.
    pub fn add_assign(&mut self, other: &TensorF) {
        assert_eq!(self.shape, other.shape);
        let od = other.data();
        for (a, b) in self.data_mut().iter_mut().zip(od) {
            *a += *b;
        }
    }

    pub fn mul(&self, other: &TensorF) -> TensorF {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: Arc::new(
                self.data
                    .iter()
                    .zip(other.data.iter())
                    .map(|(a, b)| a * b)
                    .collect(),
            ),
        }
    }

    /// In-place elementwise multiply (allocation-free twin of
    /// [`TensorF::mul`]; bit-identical by IEEE commutativity).
    pub fn mul_assign(&mut self, other: &TensorF) {
        assert_eq!(self.shape, other.shape);
        let od = other.data();
        for (a, b) in self.data_mut().iter_mut().zip(od) {
            *a *= *b;
        }
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }
}

impl<T> fmt::Debug for Tensor<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{}]", self.shape, self.data.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_roundtrip() {
        let mut t = TensorF::zeros(&[1, 2, 3, 4]);
        t.set4(0, 1, 2, 3, 7.5);
        assert_eq!(t.at4(0, 1, 2, 3), 7.5);
        assert_eq!(t.data()[1 * 12 + 2 * 4 + 3], 7.5);
    }

    #[test]
    fn concat_and_slice_roundtrip() {
        let a = TensorF::from_vec(&[1, 1, 2, 2], vec![1., 2., 3., 4.]);
        let b = TensorF::from_vec(&[1, 2, 2, 2],
                                  vec![5., 6., 7., 8., 9., 10., 11., 12.]);
        let cat = TensorF::concat_channels(&[&a, &b]);
        assert_eq!(cat.shape(), &[1, 3, 2, 2]);
        assert_eq!(cat.slice_channels(0, 1), a);
        assert_eq!(cat.slice_channels(1, 3), b);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        TensorF::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn plane_is_contiguous() {
        let t = TensorF::from_vec(&[1, 2, 1, 2], vec![1., 2., 3., 4.]);
        assert_eq!(t.plane(1), &[3., 4.]);
    }

    #[test]
    fn clone_shares_payload_until_mutation() {
        let a = TensorF::from_vec(&[1, 1, 1, 4], vec![1., 2., 3., 4.]);
        let mut b = a.clone();
        assert!(a.shares_payload_with(&b), "clone is a handle, not a copy");
        assert!(!a.is_unique() && !b.is_unique());
        // first mutation of the clone triggers exactly one CoW copy
        b.data_mut()[0] = 9.0;
        assert!(!a.shares_payload_with(&b));
        assert!(a.is_unique() && b.is_unique());
        assert_eq!(a.data(), &[1., 2., 3., 4.], "original untouched by CoW");
        assert_eq!(b.data(), &[9., 2., 3., 4.]);
    }

    #[test]
    fn unique_data_recovery_respects_aliasing() {
        let a = TensorI16::from_vec(&[1, 1, 1, 3], vec![1, 2, 3]);
        let b = a.clone();
        // aliased: neither handle can take the payload out
        assert!(b.try_unique_data().is_none());
        // ...but the alias drop above made `a` unique again
        let v = a.try_unique_data().expect("last handle owns the payload");
        assert_eq!(v, vec![1, 2, 3]);
        // into_data on a shared handle falls back to a copy
        let c = TensorI16::from_vec(&[1, 1, 1, 2], vec![7, 8]);
        let d = c.clone();
        assert_eq!(c.into_data(), vec![7, 8]);
        assert_eq!(d.data(), &[7, 8]);
    }
}
