//! Minimal dense tensor (row-major, owned) used by the software operators
//! and the CPU-only baselines.
//!
//! The request path manipulates small NCHW maps (at most 64x32x48), so a
//! simple `Vec`-backed container with contiguous row-major layout is both
//! sufficient and cache-friendly. No views/strides: the paper's software
//! side also works on packed buffers in CMA memory.

use std::fmt;

/// Dense row-major tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor<T> {
    shape: Vec<usize>,
    data: Vec<T>,
}

pub type TensorF = Tensor<f32>;
pub type TensorI16 = Tensor<i16>;
pub type TensorI32 = Tensor<i32>;
pub type TensorI8 = Tensor<i8>;

impl<T: Copy + Default> Tensor<T> {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![T::default(); n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn full(shape: &[usize], v: T) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn data(&self) -> &[T] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<T> {
        self.data
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    // --- NCHW helpers (the only layout used on the request path) ---------

    /// (N, C, H, W) of a 4-D tensor.
    #[inline]
    pub fn nchw(&self) -> (usize, usize, usize, usize) {
        assert_eq!(self.shape.len(), 4, "expected 4-D, got {:?}", self.shape);
        (self.shape[0], self.shape[1], self.shape[2], self.shape[3])
    }

    #[inline]
    pub fn at4(&self, n: usize, c: usize, h: usize, w: usize) -> T {
        let (_, cc, hh, ww) = self.nchw();
        self.data[((n * cc + c) * hh + h) * ww + w]
    }

    #[inline]
    pub fn set4(&mut self, n: usize, c: usize, h: usize, w: usize, v: T) {
        let (_, cc, hh, ww) = self.nchw();
        self.data[((n * cc + c) * hh + h) * ww + w] = v;
    }

    /// Contiguous channel plane (h*w slice) of batch 0.
    #[inline]
    pub fn plane(&self, c: usize) -> &[T] {
        let (_, cc, hh, ww) = self.nchw();
        assert!(c < cc);
        &self.data[c * hh * ww..(c + 1) * hh * ww]
    }

    #[inline]
    pub fn plane_mut(&mut self, c: usize) -> &mut [T] {
        let (_, cc, hh, ww) = self.nchw();
        assert!(c < cc);
        &mut self.data[c * hh * ww..(c + 1) * hh * ww]
    }

    /// Concatenate along the channel axis (dim 1), batch 1 assumed.
    pub fn concat_channels(parts: &[&Tensor<T>]) -> Self {
        assert!(!parts.is_empty());
        let (_, _, h, w) = parts[0].nchw();
        let c_total: usize = parts.iter().map(|p| p.nchw().1).sum();
        let mut out = Vec::with_capacity(c_total * h * w);
        for p in parts {
            let (_, _, ph, pw) = p.nchw();
            assert_eq!((ph, pw), (h, w), "spatial mismatch in concat");
            out.extend_from_slice(p.data());
        }
        Tensor::from_vec(&[1, c_total, h, w], out)
    }

    /// Channel slice [c0, c1) (dim 1), batch 1 assumed.
    pub fn slice_channels(&self, c0: usize, c1: usize) -> Self {
        let (_, c, h, w) = self.nchw();
        assert!(c0 < c1 && c1 <= c);
        let data = self.data[c0 * h * w..c1 * h * w].to_vec();
        Tensor::from_vec(&[1, c1 - c0, h, w], data)
    }
}

impl TensorF {
    pub fn map(&self, f: impl Fn(f32) -> f32) -> TensorF {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn add(&self, other: &TensorF) -> TensorF {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a + b)
                .collect(),
        }
    }

    /// In-place elementwise add — the allocation-free twin of
    /// [`TensorF::add`] (IEEE addition is commutative, so `a.add_assign(b)`
    /// is bit-identical to `b.add(a)` too).
    pub fn add_assign(&mut self, other: &TensorF) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += *b;
        }
    }

    pub fn mul(&self, other: &TensorF) -> TensorF {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a * b)
                .collect(),
        }
    }

    /// In-place elementwise multiply (allocation-free twin of
    /// [`TensorF::mul`]; bit-identical by IEEE commutativity).
    pub fn mul_assign(&mut self, other: &TensorF) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a *= *b;
        }
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }
}

impl<T> fmt::Debug for Tensor<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{}]", self.shape, self.data.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_roundtrip() {
        let mut t = TensorF::zeros(&[1, 2, 3, 4]);
        t.set4(0, 1, 2, 3, 7.5);
        assert_eq!(t.at4(0, 1, 2, 3), 7.5);
        assert_eq!(t.data()[1 * 12 + 2 * 4 + 3], 7.5);
    }

    #[test]
    fn concat_and_slice_roundtrip() {
        let a = TensorF::from_vec(&[1, 1, 2, 2], vec![1., 2., 3., 4.]);
        let b = TensorF::from_vec(&[1, 2, 2, 2],
                                  vec![5., 6., 7., 8., 9., 10., 11., 12.]);
        let cat = TensorF::concat_channels(&[&a, &b]);
        assert_eq!(cat.shape(), &[1, 3, 2, 2]);
        assert_eq!(cat.slice_channels(0, 1), a);
        assert_eq!(cat.slice_channels(1, 3), b);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        TensorF::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn plane_is_contiguous() {
        let t = TensorF::from_vec(&[1, 2, 1, 2], vec![1., 2., 3., 4.]);
        assert_eq!(t.plane(1), &[3., 4.]);
    }
}
