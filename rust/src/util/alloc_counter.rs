//! Test-only counting allocator (`--features count-allocs`): wraps the
//! system allocator and counts, **per thread**, every allocation at or
//! above [`PAYLOAD_BYTES`]. This is the instrument behind
//! `rust/tests/alloc_free.rs`, which pins that the ownership-transferring
//! submit path performs zero payload-sized allocations in steady state.
//!
//! Per-thread counting is deliberate: the backend worker and the extern
//! pool legitimately allocate segment *outputs* concurrently with a
//! submit, so a process-global counter could not isolate the submitting
//! thread's behaviour. The thread-locals are `const`-initialised `Cell`s
//! (no destructor, no lazy allocation), so counting from inside the
//! allocator cannot recurse; `try_with` makes TLS teardown benign.
//!
//! The feature only swaps the accounting wrapper in front of the system
//! allocator — allocation behaviour under test is identical to a normal
//! build.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

/// Allocations at or above this size count as "payload-sized". Tensor
/// payloads on the request path start at a few KiB (the quantized input
/// image is ~36 KiB); handles, shape vectors, queue nodes and channel
/// plumbing are all far below it.
pub const PAYLOAD_BYTES: usize = 4096;

thread_local! {
    static LARGE_ALLOCS: Cell<u64> = const { Cell::new(0) };
    static LARGE_BYTES: Cell<u64> = const { Cell::new(0) };
}

#[inline]
fn note(size: usize) {
    if size >= PAYLOAD_BYTES {
        let _ = LARGE_ALLOCS.try_with(|c| c.set(c.get() + 1));
        let _ = LARGE_BYTES.try_with(|c| c.set(c.get() + size as u64));
    }
}

/// The `#[global_allocator]` installed when `count-allocs` is enabled.
pub struct CountingAlloc;

// SAFETY: defers entirely to `System`; the bookkeeping touches only
// const-initialised thread-local `Cell`s and never allocates.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        note(layout.size());
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        note(layout.size());
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // count growth into the payload range (a shrinking or
        // still-small realloc moves no payload-sized memory)
        if new_size >= PAYLOAD_BYTES && new_size > layout.size() {
            note(new_size);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[cfg(feature = "count-allocs")]
#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Zero this thread's counters (call at the start of a measured window).
pub fn reset_thread_counters() {
    let _ = LARGE_ALLOCS.try_with(|c| c.set(0));
    let _ = LARGE_BYTES.try_with(|c| c.set(0));
}

/// Payload-sized allocations on this thread since the last reset.
pub fn thread_large_allocs() -> u64 {
    LARGE_ALLOCS.try_with(|c| c.get()).unwrap_or(0)
}

/// Bytes of payload-sized allocations on this thread since the last
/// reset.
pub fn thread_large_bytes() -> u64 {
    LARGE_BYTES.try_with(|c| c.get()).unwrap_or(0)
}

#[cfg(all(test, feature = "count-allocs"))]
mod tests {
    use super::*;

    #[test]
    fn counts_only_payload_sized_allocations_on_this_thread() {
        reset_thread_counters();
        let small = vec![0u8; 64];
        assert_eq!(thread_large_allocs(), 0, "small allocs don't count");
        let big = vec![0u8; 2 * PAYLOAD_BYTES];
        assert!(thread_large_allocs() >= 1);
        assert!(thread_large_bytes() >= 2 * PAYLOAD_BYTES as u64);
        drop((small, big));
        // another thread's allocations are invisible here
        reset_thread_counters();
        std::thread::scope(|s| {
            s.spawn(|| {
                let _v = vec![0u8; 4 * PAYLOAD_BYTES];
            });
        });
        assert_eq!(thread_large_allocs(), 0);
    }
}
