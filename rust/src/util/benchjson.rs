//! Machine-readable bench output: `BENCH_conv.json` records the repo's
//! perf trajectory instead of scrolling it away in stdout.
//!
//! The schema is a flat JSON array of flat objects:
//!
//! ```json
//! [
//!   {"op": "conv2d_q_3x3", "shape": "x=1x64x32x48 w=32x64x3x3 s=1",
//!    "ns_per_iter": 412345.0, "gops": 13.7, "threads": 1}
//! ]
//! ```
//!
//! Benches *merge* into the file keyed by `(op, threads)` — `ops_micro`
//! and the `conv` bench both write `BENCH_conv.json` without clobbering
//! each other's records. The writer/parser below handle exactly this
//! schema (no external JSON crate is vendored); [`validate`] is what the
//! CI bench-smoke step runs after `--smoke`.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// One kernel measurement.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRecord {
    /// Kernel + variant name, e.g. `conv2d_q_3x3`.
    pub op: String,
    /// Human-readable shape key, e.g. `x=1x64x32x48 w=32x64x3x3 s=1`.
    pub shape: String,
    /// Median wall time per iteration, nanoseconds.
    pub ns_per_iter: f64,
    /// Giga-ops/s (2 ops per MAC) at that median.
    pub gops: f64,
    /// Conv worker threads the measurement used.
    pub threads: usize,
    /// Submit-path copy accounting (PR 5, `benches/serve.rs` only):
    /// payload bytes the *copying* submit scheme would have deep-copied
    /// for this run — i.e. the input bytes that crossed the submit
    /// queue. `None` for records without a submit path.
    pub copy_bytes_before: Option<f64>,
    /// Payload bytes actually deep-copied on the submit path (zero
    /// under ownership transfer; pinned by `rust/tests/alloc_free.rs`).
    pub copy_bytes_after: Option<f64>,
    /// Backend shards the measurement ran over (PR 6 shard-scaling
    /// records in `benches/serve.rs`). `None` for single-backend runs.
    pub shards: Option<usize>,
    /// Sessions migrated between shards during the measurement. Only
    /// meaningful alongside `shards`.
    pub migrations: Option<usize>,
    /// Checkpoint traffic the measurement wrote (PR 7 durability
    /// records in `benches/serve.rs`): total bytes of session
    /// checkpoints. `None` for records without a durability path.
    pub checkpoint_bytes: Option<f64>,
    /// Wall seconds spent restoring sessions from checkpoints. Only
    /// meaningful alongside `checkpoint_bytes`.
    pub restore_seconds: Option<f64>,
    /// HW-call retries the recovery policy issued during the
    /// measurement (PR 7 chaos records). `None` when retry is off.
    pub retries: Option<usize>,
    /// Continuous-scheduling records (PR 8, `benches/serve.rs`):
    /// fraction of formed-round capacity actually filled with ready
    /// frames, in `0..=1`. `None` for lockstep records.
    pub fill_ratio: Option<f64>,
    /// Fraction of served frames that missed their frame deadline, in
    /// `0..=1`. Only meaningful alongside `fill_ratio`.
    pub deadline_miss_rate: Option<f64>,
    /// Streams shed (dropped after a served prefix) during the
    /// measurement. Only meaningful alongside `fill_ratio`.
    pub shed: Option<usize>,
    /// Process-isolation records (PR 9, `benches/serve.rs`): worker
    /// processes the measurement served through. `None` for in-process
    /// backends.
    pub workers: Option<usize>,
    /// Wall-time ratio of process-isolated over in-process serving of
    /// the same workload (1.0 = free isolation). Only meaningful
    /// alongside `workers`.
    pub ipc_overhead: Option<f64>,
    /// Supervised worker restarts during the measurement. Only
    /// meaningful alongside `workers`.
    pub restarts: Option<usize>,
    /// Data-plane-integrity records (PR 10, `benches/serve.rs`):
    /// wall-time ratio of guarded over unguarded serving of the same
    /// clean workload (1.0 = free screening). `None` for unguarded
    /// records.
    pub guard_overhead: Option<f64>,
    /// Streams quarantined (downgraded or shed by the guard ladder)
    /// during the measurement. Only meaningful alongside
    /// `guard_overhead`.
    pub quarantined: Option<usize>,
}

impl BenchRecord {
    /// Records with the same key overwrite each other on merge.
    pub fn key(&self) -> (String, usize) {
        (self.op.clone(), self.threads)
    }

    /// A record with no copy accounting (every bench except `serve`).
    pub fn timing(
        op: impl Into<String>,
        shape: impl Into<String>,
        ns_per_iter: f64,
        gops: f64,
        threads: usize,
    ) -> Self {
        BenchRecord {
            op: op.into(),
            shape: shape.into(),
            ns_per_iter,
            gops,
            threads,
            copy_bytes_before: None,
            copy_bytes_after: None,
            shards: None,
            migrations: None,
            checkpoint_bytes: None,
            restore_seconds: None,
            retries: None,
            fill_ratio: None,
            deadline_miss_rate: None,
            shed: None,
            workers: None,
            ipc_overhead: None,
            restarts: None,
            guard_overhead: None,
            quarantined: None,
        }
    }
}

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Serialize records to the schema above (stable field order, one object
/// per line — diffs stay readable in git).
pub fn to_json(records: &[BenchRecord]) -> String {
    let mut out = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        let _ = write!(
            out,
            "  {{\"op\": \"{}\", \"shape\": \"{}\", \"ns_per_iter\": {:.1}, \
             \"gops\": {:.3}, \"threads\": {}",
            esc(&r.op),
            esc(&r.shape),
            r.ns_per_iter,
            r.gops,
            r.threads,
        );
        if let Some(b) = r.copy_bytes_before {
            let _ = write!(out, ", \"copy_bytes_before\": {b:.1}");
        }
        if let Some(a) = r.copy_bytes_after {
            let _ = write!(out, ", \"copy_bytes_after\": {a:.1}");
        }
        if let Some(s) = r.shards {
            let _ = write!(out, ", \"shards\": {s}");
        }
        if let Some(m) = r.migrations {
            let _ = write!(out, ", \"migrations\": {m}");
        }
        if let Some(c) = r.checkpoint_bytes {
            let _ = write!(out, ", \"checkpoint_bytes\": {c:.1}");
        }
        if let Some(rs) = r.restore_seconds {
            let _ = write!(out, ", \"restore_seconds\": {rs:.6}");
        }
        if let Some(n) = r.retries {
            let _ = write!(out, ", \"retries\": {n}");
        }
        if let Some(f) = r.fill_ratio {
            let _ = write!(out, ", \"fill_ratio\": {f:.4}");
        }
        if let Some(m) = r.deadline_miss_rate {
            let _ = write!(out, ", \"deadline_miss_rate\": {m:.4}");
        }
        if let Some(s) = r.shed {
            let _ = write!(out, ", \"shed\": {s}");
        }
        if let Some(w) = r.workers {
            let _ = write!(out, ", \"workers\": {w}");
        }
        if let Some(o) = r.ipc_overhead {
            let _ = write!(out, ", \"ipc_overhead\": {o:.4}");
        }
        if let Some(n) = r.restarts {
            let _ = write!(out, ", \"restarts\": {n}");
        }
        if let Some(g) = r.guard_overhead {
            let _ = write!(out, ", \"guard_overhead\": {g:.4}");
        }
        if let Some(q) = r.quarantined {
            let _ = write!(out, ", \"quarantined\": {q}");
        }
        let _ = write!(
            out,
            "}}{}",
            if i + 1 < records.len() { ",\n" } else { "\n" },
        );
    }
    out.push(']');
    out.push('\n');
    out
}

// --- minimal JSON reader for the schema above ------------------------------

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.s.len() && self.s[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        self.skip_ws();
        if self.i < self.s.len() && self.s[self.i] == c {
            self.i += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at byte {} of bench JSON",
                c as char,
                self.i
            )
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.skip_ws();
        self.s.get(self.i).copied()
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        // collect raw bytes (UTF-8 passes through) and convert once
        let mut out: Vec<u8> = Vec::new();
        while self.i < self.s.len() {
            let c = self.s[self.i];
            self.i += 1;
            match c {
                b'"' => return Ok(String::from_utf8(out)?),
                b'\\' => {
                    let e = *self
                        .s
                        .get(self.i)
                        .context("dangling escape in bench JSON")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push(b'"'),
                        b'\\' => out.push(b'\\'),
                        b'n' => out.push(b'\n'),
                        b'u' => {
                            let hex = self
                                .s
                                .get(self.i..self.i + 4)
                                .context("short \\u escape")?;
                            let v = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            let ch = char::from_u32(v)
                                .context("bad \\u escape")?;
                            let mut buf = [0u8; 4];
                            out.extend_from_slice(
                                ch.encode_utf8(&mut buf).as_bytes(),
                            );
                            self.i += 4;
                        }
                        other => bail!("unsupported escape '\\{}'", other as char),
                    }
                }
                c => out.push(c),
            }
        }
        bail!("unterminated string in bench JSON")
    }

    fn number(&mut self) -> Result<f64> {
        self.skip_ws();
        let start = self.i;
        while self.i < self.s.len()
            && matches!(self.s[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.s[start..self.i])?
            .parse::<f64>()
            .with_context(|| format!("bad number at byte {start}"))
    }
}

/// Parse the schema emitted by [`to_json`]. Unknown keys are rejected —
/// the file is ours, drift means a bug.
pub fn from_json(text: &str) -> Result<Vec<BenchRecord>> {
    let mut p = Parser { s: text.as_bytes(), i: 0 };
    p.eat(b'[')?;
    let mut records = Vec::new();
    if p.peek() == Some(b']') {
        p.eat(b']')?;
        return Ok(records);
    }
    loop {
        p.eat(b'{')?;
        let (mut op, mut shape) = (None, None);
        let (mut ns, mut gops, mut threads) = (None, None, None);
        let (mut cb_before, mut cb_after) = (None, None);
        let (mut shards, mut migrations) = (None, None);
        let (mut ckpt_bytes, mut restore_s, mut retries) = (None, None, None);
        let (mut fill, mut miss_rate, mut shed) = (None, None, None);
        let (mut workers, mut ipc_overhead, mut restarts) = (None, None, None);
        let (mut guard_overhead, mut quarantined) = (None, None);
        loop {
            let key = p.string()?;
            p.eat(b':')?;
            match key.as_str() {
                "op" => op = Some(p.string()?),
                "shape" => shape = Some(p.string()?),
                "ns_per_iter" => ns = Some(p.number()?),
                "gops" => gops = Some(p.number()?),
                "threads" => threads = Some(p.number()? as usize),
                "copy_bytes_before" => cb_before = Some(p.number()?),
                "copy_bytes_after" => cb_after = Some(p.number()?),
                "shards" => shards = Some(p.number()? as usize),
                "migrations" => migrations = Some(p.number()? as usize),
                "checkpoint_bytes" => ckpt_bytes = Some(p.number()?),
                "restore_seconds" => restore_s = Some(p.number()?),
                "retries" => retries = Some(p.number()? as usize),
                "fill_ratio" => fill = Some(p.number()?),
                "deadline_miss_rate" => miss_rate = Some(p.number()?),
                "shed" => shed = Some(p.number()? as usize),
                "workers" => workers = Some(p.number()? as usize),
                "ipc_overhead" => ipc_overhead = Some(p.number()?),
                "restarts" => restarts = Some(p.number()? as usize),
                "guard_overhead" => guard_overhead = Some(p.number()?),
                "quarantined" => quarantined = Some(p.number()? as usize),
                other => bail!("unknown bench-record key '{other}'"),
            }
            match p.peek() {
                Some(b',') => p.eat(b',')?,
                _ => break,
            }
        }
        p.eat(b'}')?;
        records.push(BenchRecord {
            op: op.context("record missing 'op'")?,
            shape: shape.context("record missing 'shape'")?,
            ns_per_iter: ns.context("record missing 'ns_per_iter'")?,
            gops: gops.context("record missing 'gops'")?,
            threads: threads.context("record missing 'threads'")?,
            copy_bytes_before: cb_before,
            copy_bytes_after: cb_after,
            shards,
            migrations,
            checkpoint_bytes: ckpt_bytes,
            restore_seconds: restore_s,
            retries,
            fill_ratio: fill,
            deadline_miss_rate: miss_rate,
            shed,
            workers,
            ipc_overhead,
            restarts,
            guard_overhead,
            quarantined,
        });
        match p.peek() {
            Some(b',') => p.eat(b',')?,
            _ => break,
        }
    }
    p.eat(b']')?;
    Ok(records)
}

/// Merge `fresh` into the records already in `path` (keyed by
/// `(op, threads)`; existing records keep their position, new ones
/// append) and rewrite the file. A missing file starts empty; an
/// existing-but-unparseable file is an error — silently wiping the
/// accumulated perf history would defeat the file's purpose.
pub fn merge_into(path: &Path, fresh: &[BenchRecord]) -> Result<()> {
    let mut records = match std::fs::read_to_string(path) {
        Ok(t) => from_json(&t).with_context(|| {
            format!(
                "{} exists but does not parse; fix or remove it before \
                 merging new records",
                path.display()
            )
        })?,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
        Err(e) => {
            return Err(e).with_context(|| {
                format!("reading existing {}", path.display())
            })
        }
    };
    for r in fresh {
        match records.iter_mut().find(|e| e.key() == r.key()) {
            Some(slot) => *slot = r.clone(),
            None => records.push(r.clone()),
        }
    }
    std::fs::write(path, to_json(&records))
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// Shared bench-`main` epilogue: write `records` — to the
/// `BENCH_conv.smoke.json` scratch file when `smoke` (cold-iteration
/// timings must never overwrite the real perf record), else merged into
/// `BENCH_conv.json` — then validate the schema, printing the outcome and
/// exiting non-zero on drift.
pub fn write_and_validate(smoke: bool, records: &[BenchRecord]) {
    write_and_validate_named("BENCH_conv", smoke, records);
}

/// As [`write_and_validate`] for an arbitrary record-file stem: the
/// records land in `{stem}.json` (or the `{stem}.smoke.json` scratch
/// file under `--smoke`). `benches/elementwise.rs` uses `BENCH_ops`.
pub fn write_and_validate_named(stem: &str, smoke: bool, records: &[BenchRecord]) {
    let name = if smoke {
        format!("{stem}.smoke.json")
    } else {
        format!("{stem}.json")
    };
    let path = Path::new(&name);
    if smoke {
        let _ = std::fs::remove_file(path);
    }
    if let Err(e) = merge_into(path, records) {
        eprintln!("writing {}: {e:#}", path.display());
        std::process::exit(1);
    }
    match validate(path) {
        Ok(n) => println!("{} schema OK ({n} records)", path.display()),
        Err(e) => {
            eprintln!("{} schema INVALID: {e:#}", path.display());
            std::process::exit(1);
        }
    }
}

/// Schema check for the CI bench-smoke step: the file parses, is
/// non-empty, and every record has a finite positive time and a thread
/// count.
pub fn validate(path: &Path) -> Result<usize> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let records = from_json(&text)?;
    anyhow::ensure!(!records.is_empty(), "no bench records in file");
    for r in &records {
        anyhow::ensure!(!r.op.is_empty(), "empty op name");
        anyhow::ensure!(
            r.ns_per_iter.is_finite() && r.ns_per_iter > 0.0,
            "op '{}': bad ns_per_iter {}",
            r.op,
            r.ns_per_iter
        );
        anyhow::ensure!(
            r.gops.is_finite() && r.gops >= 0.0,
            "op '{}': bad gops {}",
            r.op,
            r.gops
        );
        anyhow::ensure!(r.threads >= 1, "op '{}': bad thread count", r.op);
        // copy accounting (serve records): finite, non-negative, and the
        // ownership-transferring path can never copy more than the
        // copying scheme it replaced
        for (k, v) in [
            ("copy_bytes_before", r.copy_bytes_before),
            ("copy_bytes_after", r.copy_bytes_after),
        ] {
            if let Some(v) = v {
                anyhow::ensure!(
                    v.is_finite() && v >= 0.0,
                    "op '{}': bad {k} {v}",
                    r.op
                );
            }
        }
        if let (Some(b), Some(a)) = (r.copy_bytes_before, r.copy_bytes_after) {
            anyhow::ensure!(
                a <= b,
                "op '{}': copy_bytes_after {a} exceeds copy_bytes_before {b}",
                r.op
            );
        }
        // shard-scaling records: a fleet has >= 1 shards, and a
        // migration count only means something with a fleet size
        if let Some(s) = r.shards {
            anyhow::ensure!(s >= 1, "op '{}': bad shard count {s}", r.op);
        }
        anyhow::ensure!(
            r.migrations.is_none() || r.shards.is_some(),
            "op '{}': migrations without a shards field",
            r.op
        );
        // durability records (PR 7): finite and non-negative, and a
        // restore time only means something next to checkpoint traffic
        for (k, v) in [
            ("checkpoint_bytes", r.checkpoint_bytes),
            ("restore_seconds", r.restore_seconds),
        ] {
            if let Some(v) = v {
                anyhow::ensure!(
                    v.is_finite() && v >= 0.0,
                    "op '{}': bad {k} {v}",
                    r.op
                );
            }
        }
        anyhow::ensure!(
            r.restore_seconds.is_none() || r.checkpoint_bytes.is_some(),
            "op '{}': restore_seconds without a checkpoint_bytes field",
            r.op
        );
        // continuous-scheduling records (PR 8): both ratios are
        // fractions, and the companion fields only mean something next
        // to a fill ratio
        for (k, v) in [
            ("fill_ratio", r.fill_ratio),
            ("deadline_miss_rate", r.deadline_miss_rate),
        ] {
            if let Some(v) = v {
                anyhow::ensure!(
                    v.is_finite() && (0.0..=1.0).contains(&v),
                    "op '{}': {k} {v} is not a fraction in 0..=1",
                    r.op
                );
            }
        }
        anyhow::ensure!(
            (r.deadline_miss_rate.is_none() && r.shed.is_none())
                || r.fill_ratio.is_some(),
            "op '{}': scheduler fields without a fill_ratio field",
            r.op
        );
        // process-isolation records (PR 9): a worker fleet has >= 1
        // processes, the overhead ratio is finite and non-negative, and
        // the companion fields only mean something next to a fleet size
        if let Some(w) = r.workers {
            anyhow::ensure!(w >= 1, "op '{}': bad worker count {w}", r.op);
        }
        if let Some(o) = r.ipc_overhead {
            anyhow::ensure!(
                o.is_finite() && o >= 0.0,
                "op '{}': bad ipc_overhead {o}",
                r.op
            );
        }
        anyhow::ensure!(
            (r.ipc_overhead.is_none() && r.restarts.is_none())
                || r.workers.is_some(),
            "op '{}': supervision fields without a workers field",
            r.op
        );
        // data-plane-integrity records (PR 10): the overhead ratio is
        // finite and non-negative, and a quarantine count only means
        // something next to a guarded measurement
        if let Some(g) = r.guard_overhead {
            anyhow::ensure!(
                g.is_finite() && g >= 0.0,
                "op '{}': bad guard_overhead {g}",
                r.op
            );
        }
        anyhow::ensure!(
            r.quarantined.is_none() || r.guard_overhead.is_some(),
            "op '{}': quarantined without a guard_overhead field",
            r.op
        );
    }
    Ok(records.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(op: &str, threads: usize, ns: f64) -> BenchRecord {
        BenchRecord::timing(op, "x=1x2x3x4 w=2x2x3x3 s=1", ns, 1.5, threads)
    }

    #[test]
    fn roundtrip_preserves_records() {
        let recs =
            vec![rec("conv2d_q_3x3", 1, 1234.5), rec("conv2d_q_3x3", 4, 400.0)];
        let parsed = from_json(&to_json(&recs)).unwrap();
        assert_eq!(parsed, recs);
    }

    #[test]
    fn empty_array_roundtrips() {
        assert_eq!(from_json("[]\n").unwrap(), vec![]);
        assert_eq!(from_json(&to_json(&[])).unwrap(), vec![]);
    }

    #[test]
    fn escapes_survive() {
        let mut r = rec("odd\"op\\name", 1, 5.0);
        r.shape = "line\nbreak".into();
        let parsed = from_json(&to_json(&[r.clone()])).unwrap();
        assert_eq!(parsed, vec![r]);
    }

    #[test]
    fn copy_bytes_fields_roundtrip_and_validate() {
        let mut r = rec("serve_pipelined_k2", 2, 100.0);
        r.copy_bytes_before = Some(1_234_567.0);
        r.copy_bytes_after = Some(0.0);
        let parsed = from_json(&to_json(&[r.clone()])).unwrap();
        assert_eq!(parsed, vec![r.clone()]);
        // records without the fields keep emitting the old schema
        let bare = to_json(&[rec("a", 1, 1.0)]);
        assert!(!bare.contains("copy_bytes"));
        // validation: after > before is schema drift
        let dir = std::env::temp_dir()
            .join(format!("fadec_benchjson_copy_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_serve.json");
        let _ = std::fs::remove_file(&path);
        merge_into(&path, &[r]).unwrap();
        assert_eq!(validate(&path).unwrap(), 1);
        let mut bad = rec("x", 1, 1.0);
        bad.copy_bytes_before = Some(10.0);
        bad.copy_bytes_after = Some(20.0);
        std::fs::write(&path, to_json(&[bad])).unwrap();
        assert!(validate(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn shard_fields_roundtrip_and_validate() {
        let mut r = rec("serve_sharded_k4", 1, 100.0);
        r.shards = Some(4);
        r.migrations = Some(2);
        let parsed = from_json(&to_json(&[r.clone()])).unwrap();
        assert_eq!(parsed, vec![r.clone()]);
        // single-backend records keep emitting the old schema
        let bare = to_json(&[rec("a", 1, 1.0)]);
        assert!(!bare.contains("shards"));
        assert!(!bare.contains("migrations"));
        let dir = std::env::temp_dir()
            .join(format!("fadec_benchjson_shard_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_serve.json");
        let _ = std::fs::remove_file(&path);
        merge_into(&path, &[r]).unwrap();
        assert_eq!(validate(&path).unwrap(), 1);
        // a zero-shard fleet is schema drift
        let mut bad = rec("x", 1, 1.0);
        bad.shards = Some(0);
        std::fs::write(&path, to_json(&[bad])).unwrap();
        assert!(validate(&path).is_err());
        // so is a migration count with no fleet size
        let mut bad = rec("x", 1, 1.0);
        bad.migrations = Some(1);
        std::fs::write(&path, to_json(&[bad])).unwrap();
        assert!(validate(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn durability_fields_roundtrip_and_validate() {
        let mut r = rec("serve_checkpoint_restart", 1, 100.0);
        r.checkpoint_bytes = Some(2_048_000.0);
        r.restore_seconds = Some(0.0125);
        r.retries = Some(4);
        let parsed = from_json(&to_json(&[r.clone()])).unwrap();
        assert_eq!(parsed, vec![r.clone()]);
        // fault-free records keep emitting the old schema
        let bare = to_json(&[rec("a", 1, 1.0)]);
        assert!(!bare.contains("checkpoint_bytes"));
        assert!(!bare.contains("restore_seconds"));
        assert!(!bare.contains("retries"));
        let dir = std::env::temp_dir()
            .join(format!("fadec_benchjson_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_serve.json");
        let _ = std::fs::remove_file(&path);
        merge_into(&path, &[r]).unwrap();
        assert_eq!(validate(&path).unwrap(), 1);
        // a negative restore time is schema drift
        let mut bad = rec("x", 1, 1.0);
        bad.checkpoint_bytes = Some(10.0);
        bad.restore_seconds = Some(-0.5);
        std::fs::write(&path, to_json(&[bad])).unwrap();
        assert!(validate(&path).is_err());
        // so is a restore time with no checkpoint traffic
        let mut bad = rec("x", 1, 1.0);
        bad.restore_seconds = Some(0.5);
        std::fs::write(&path, to_json(&[bad])).unwrap();
        assert!(validate(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn scheduler_fields_roundtrip_and_validate() {
        let mut r = rec("serve_continuous", 1, 100.0);
        r.fill_ratio = Some(0.8125);
        r.deadline_miss_rate = Some(0.05);
        r.shed = Some(1);
        let parsed = from_json(&to_json(&[r.clone()])).unwrap();
        assert_eq!(parsed, vec![r.clone()]);
        // lockstep records keep emitting the old schema
        let bare = to_json(&[rec("a", 1, 1.0)]);
        assert!(!bare.contains("fill_ratio"));
        assert!(!bare.contains("deadline_miss_rate"));
        assert!(!bare.contains("shed"));
        let dir = std::env::temp_dir()
            .join(format!("fadec_benchjson_sched_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_serve.json");
        let _ = std::fs::remove_file(&path);
        merge_into(&path, &[r]).unwrap();
        assert_eq!(validate(&path).unwrap(), 1);
        // a fill ratio outside 0..=1 is schema drift
        let mut bad = rec("x", 1, 1.0);
        bad.fill_ratio = Some(1.5);
        std::fs::write(&path, to_json(&[bad])).unwrap();
        assert!(validate(&path).is_err());
        // so is a shed count with no fill ratio
        let mut bad = rec("x", 1, 1.0);
        bad.shed = Some(2);
        std::fs::write(&path, to_json(&[bad])).unwrap();
        assert!(validate(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn supervision_fields_roundtrip_and_validate() {
        let mut r = rec("serve_isolated_k2", 1, 100.0);
        r.workers = Some(2);
        r.ipc_overhead = Some(1.0625);
        r.restarts = Some(1);
        let parsed = from_json(&to_json(&[r.clone()])).unwrap();
        assert_eq!(parsed, vec![r.clone()]);
        // in-process records keep emitting the old schema
        let bare = to_json(&[rec("a", 1, 1.0)]);
        assert!(!bare.contains("workers"));
        assert!(!bare.contains("ipc_overhead"));
        assert!(!bare.contains("restarts"));
        let dir = std::env::temp_dir()
            .join(format!("fadec_benchjson_sup_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_serve.json");
        let _ = std::fs::remove_file(&path);
        merge_into(&path, &[r]).unwrap();
        assert_eq!(validate(&path).unwrap(), 1);
        // a zero-process fleet is schema drift
        let mut bad = rec("x", 1, 1.0);
        bad.workers = Some(0);
        std::fs::write(&path, to_json(&[bad])).unwrap();
        assert!(validate(&path).is_err());
        // so is an overhead ratio with no fleet size
        let mut bad = rec("x", 1, 1.0);
        bad.ipc_overhead = Some(1.1);
        std::fs::write(&path, to_json(&[bad])).unwrap();
        assert!(validate(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn guard_fields_roundtrip_and_validate() {
        let mut r = rec("serve_guarded", 1, 100.0);
        r.guard_overhead = Some(1.0213);
        r.quarantined = Some(1);
        let parsed = from_json(&to_json(&[r.clone()])).unwrap();
        assert_eq!(parsed, vec![r.clone()]);
        // unguarded records keep emitting the old schema
        let bare = to_json(&[rec("a", 1, 1.0)]);
        assert!(!bare.contains("guard_overhead"));
        assert!(!bare.contains("quarantined"));
        let dir = std::env::temp_dir()
            .join(format!("fadec_benchjson_guard_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_serve.json");
        let _ = std::fs::remove_file(&path);
        merge_into(&path, &[r]).unwrap();
        assert_eq!(validate(&path).unwrap(), 1);
        // a non-finite overhead ratio is schema drift
        let mut bad = rec("x", 1, 1.0);
        bad.guard_overhead = Some(f64::NAN);
        std::fs::write(&path, to_json(&[bad])).unwrap();
        assert!(validate(&path).is_err());
        // so is a quarantine count with no guarded measurement
        let mut bad = rec("x", 1, 1.0);
        bad.quarantined = Some(3);
        std::fs::write(&path, to_json(&[bad])).unwrap();
        assert!(validate(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn unknown_keys_are_rejected() {
        let bad = r#"[{"op": "x", "shape": "s", "ns_per_iter": 1.0,
                       "gops": 0.1, "threads": 1, "extra": 7}]"#;
        assert!(from_json(bad).is_err());
        assert!(from_json(r#"[{"op": "x"}]"#).is_err());
    }

    #[test]
    fn merge_refuses_to_wipe_a_corrupt_file() {
        let dir = std::env::temp_dir()
            .join(format!("fadec_benchjson_corrupt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_conv.json");
        std::fs::write(&path, "[{\"op\": trunca").unwrap();
        assert!(merge_into(&path, &[rec("a", 1, 1.0)]).is_err());
        // the corrupt history is left in place for the operator to inspect
        let kept = std::fs::read_to_string(&path).unwrap();
        assert!(kept.contains("trunca"));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn merge_upserts_by_op_and_threads() {
        let dir = std::env::temp_dir()
            .join(format!("fadec_benchjson_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_conv.json");
        let _ = std::fs::remove_file(&path);
        merge_into(&path, &[rec("a", 1, 10.0), rec("b", 1, 20.0)]).unwrap();
        // same key overwrites, new thread count appends
        merge_into(&path, &[rec("a", 1, 11.0), rec("a", 4, 3.0)]).unwrap();
        let recs = from_json(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(recs.len(), 3);
        assert_eq!(recs[0].ns_per_iter, 11.0);
        assert_eq!(recs[1].op, "b");
        assert_eq!(recs[2].threads, 4);
        assert_eq!(validate(&path).unwrap(), 3);
        std::fs::remove_file(&path).unwrap();
    }
}
