//! Small self-contained utilities: a seeded PRNG for the property tests
//! (no external crates are vendored beyond `xla`/`anyhow`), timing
//! aggregation helpers, a tiny CLI argument reader, the
//! machine-readable bench-record writer (`benchjson`), and the
//! test-only counting allocator behind the `count-allocs` feature
//! (`alloc_counter`).

pub mod alloc_counter;
pub mod benchjson;

/// SplitMix64 — tiny, high-quality seeded PRNG for tests and workload
/// generation. Deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.wrapping_add(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n.max(1)
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + (self.below((hi - lo + 1) as u64) as i64)
    }

    /// Uniform float in [0, 1).
    #[inline]
    pub fn unit_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform float in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.unit_f32()
    }

    /// Standard normal via Box-Muller.
    pub fn normal_f32(&mut self) -> f32 {
        let u1 = self.unit_f32().max(1e-12);
        let u2 = self.unit_f32();
        (-2.0 * u1.ln()).sqrt() * (std::f32::consts::TAU * u2).cos()
    }
}

/// Streaming FNV-1a (64-bit) — a tiny, deterministic, platform-stable
/// content hash for fingerprinting (checkpoint compatibility checks),
/// *not* for adversarial collision resistance. `std`'s `DefaultHasher`
/// is explicitly unstable across releases, which a fingerprint persisted
/// next to checkpoints can't tolerate.
#[derive(Clone, Debug)]
pub struct Fnv64(u64);

impl Fnv64 {
    pub fn new() -> Self {
        Fnv64(0xcbf29ce484222325)
    }

    #[inline]
    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }

    /// Hash a length-prefixed byte string (so `("ab","c")` and
    /// `("a","bc")` digest differently).
    #[inline]
    pub fn write_str(&mut self, s: &str) {
        self.write(&(s.len() as u64).to_le_bytes());
        self.write(s.as_bytes());
    }

    #[inline]
    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    #[inline]
    pub fn write_i64(&mut self, v: i64) {
        self.write(&v.to_le_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

/// Median / std aggregation as reported in Table II of the paper.
#[derive(Clone, Debug, Default)]
pub struct TimingStats {
    pub samples: Vec<f64>,
}

impl TimingStats {
    pub fn push(&mut self, seconds: f64) {
        self.samples.push(seconds);
    }

    pub fn median(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        if n % 2 == 1 { s[n / 2] } else { 0.5 * (s[n / 2 - 1] + s[n / 2]) }
    }

    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len().max(1) as f64
    }

    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let v = self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.samples.len() - 1) as f64;
        v.sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }
}

/// Tiny benchmark harness (criterion is not vendored): warmup + timed
/// iterations, reporting the paper's statistics (median / std).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> TimingStats {
    for _ in 0..warmup {
        f();
    }
    let mut stats = TimingStats::default();
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        f();
        stats.push(t0.elapsed().as_secs_f64());
    }
    println!(
        "bench {name:<32} median {:>10.4} ms   std {:>8.4} ms   min {:>10.4} ms   (n={iters})",
        stats.median() * 1e3,
        stats.std() * 1e3,
        stats.min() * 1e3
    );
    stats
}

/// Minimal `--flag value` / `--switch` argument reader (no clap vendored).
#[derive(Clone, Debug)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: Vec<(String, Option<String>)>,
}

impl Args {
    pub fn parse(argv: impl Iterator<Item = String>) -> Self {
        let mut positional = Vec::new();
        let mut flags: Vec<(String, Option<String>)> = Vec::new();
        let argv: Vec<String> = argv.collect();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.push((k.to_string(), Some(v.to_string())));
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.push((name.to_string(), Some(argv[i + 1].clone())));
                    i += 1;
                } else {
                    flags.push((name.to_string(), None));
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Args { positional, flags }
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == name)
            .and_then(|(_, v)| v.as_deref())
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|(k, _)| k == name)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_unit_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.unit_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn median_and_std() {
        let mut t = TimingStats::default();
        for v in [3.0, 1.0, 2.0] {
            t.push(v);
        }
        assert_eq!(t.median(), 2.0);
        assert!((t.std() - 1.0).abs() < 1e-12);
        t.push(4.0);
        assert_eq!(t.median(), 2.5);
    }

    #[test]
    fn fnv_is_deterministic_and_prefix_sensitive() {
        let mut a = Fnv64::new();
        a.write_str("ab");
        a.write_str("c");
        let mut b = Fnv64::new();
        b.write_str("a");
        b.write_str("bc");
        assert_ne!(a.finish(), b.finish(), "length prefix separates fields");
        let mut c = Fnv64::new();
        c.write_str("ab");
        c.write_str("c");
        assert_eq!(a.finish(), c.finish());
        // known-stable digest: the fingerprint format must not drift
        let mut d = Fnv64::new();
        d.write(b"fadec");
        assert_eq!(d.finish(), 0xfa2238c1687ff5b0);
    }

    #[test]
    fn args_parsing() {
        let a = Args::parse(
            ["run", "--scene", "chess-01", "--verbose", "--n=5"]
                .iter()
                .map(|s| s.to_string()),
        );
        assert_eq!(a.positional, ["run"]);
        assert_eq!(a.get("scene"), Some("chess-01"));
        assert!(a.has("verbose"));
        assert_eq!(a.get_usize("n", 0), 5);
    }
}
