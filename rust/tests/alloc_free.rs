//! Allocation accounting for the zero-copy submit path (PR 5). Runs
//! only with `--features count-allocs`, which installs the per-thread
//! counting allocator (`util::alloc_counter`).
//!
//! The pins:
//!
//! 1. A steady-state `RefBackend::submit_batch` performs **zero**
//!    payload-sized allocations on the submitting thread — the job is
//!    enqueued by moving Arc handles, never by copying payloads (the
//!    PR-4 implementation deep-copied every input batch here).
//! 2. A steady-state `PipelineEngine::begin_round` allocates exactly
//!    one payload per stream — the image quantization — and nothing
//!    more: its FeFs submission adds zero payload-sized allocations.
//! 3. A full `run_pipelined` window moves megabytes through the submit
//!    queue while the backend's copy accounting stays at the handle
//!    level (payload bytes submitted, none cloned on the serving
//!    thread beyond the per-round quantizations).
//!
//! Worker-side allocations (segment outputs, extern-pool scratch) are
//! invisible to the per-thread counters by design — they are real work,
//! not submit-path overhead.

use std::sync::Arc;

use fadec::coordinator::{PipelineEngine, PipelineOptions, StreamServer};
use fadec::data::dataset::Scene;
use fadec::poses::Mat4;
use fadec::quant::{quantize_tensor, QTensor};
use fadec::runtime::{HwBackend, RefBackend};
use fadec::tensor::TensorF;
use fadec::util::alloc_counter::{
    reset_thread_counters, thread_large_allocs, PAYLOAD_BYTES,
};
use fadec::util::Rng;

fn random_image(seed: u64) -> TensorF {
    let mut rng = Rng::new(seed);
    let n = 3 * fadec::config::IMG_H * fadec::config::IMG_W;
    TensorF::from_vec(
        &[1, 3, fadec::config::IMG_H, fadec::config::IMG_W],
        (0..n).map(|_| rng.range_f32(-2.0, 2.0)).collect(),
    )
}

#[test]
fn steady_state_submit_batch_is_payload_allocation_free() {
    let be = RefBackend::synthetic(7);
    let id = be.resolve("fe_fs").unwrap();
    let imgs: Vec<QTensor> = (0..3u64)
        .map(|i| quantize_tensor(&random_image(i), be.qp().aexp("image")))
        .collect();
    // a quantized image really is payload-sized, so the counter would
    // see a deep copy if one happened
    assert!(imgs[0].t.len() * 2 >= PAYLOAD_BYTES);
    // warm-up: channel plumbing, queue node pools, worker start
    let owned: Vec<Vec<QTensor>> = imgs.iter().map(|q| vec![q.clone()]).collect();
    be.submit_batch(id, owned).unwrap().wait_batch().unwrap();
    // steady state: building the handle batch + submitting allocates
    // nothing payload-sized on this thread
    reset_thread_counters();
    let owned: Vec<Vec<QTensor>> = imgs.iter().map(|q| vec![q.clone()]).collect();
    let handle = be.submit_batch(id, owned).unwrap();
    assert_eq!(
        thread_large_allocs(),
        0,
        "submit path performed a payload-sized allocation"
    );
    // the submission still computes the right thing
    let outs = handle.wait_batch().unwrap();
    assert_eq!(outs.len(), imgs.len());
    let want = be.run(id, &[&imgs[0]]).unwrap();
    for (a, b) in outs[0].iter().zip(&want) {
        assert_eq!(a.t.data(), b.t.data());
    }
}

#[test]
fn begin_round_allocates_only_the_image_quantizations() {
    // begin_round = quantize N images (one payload alloc each — the
    // input DMA analog) + submit the batched FeFs. The submission must
    // contribute zero payload-sized allocations on top.
    let backend = Arc::new(RefBackend::synthetic(29));
    let qp = Arc::clone(backend.qp());
    let engine = PipelineEngine::new(
        backend as Arc<dyn HwBackend>,
        qp,
        PipelineOptions::default(),
    )
    .unwrap();
    let n_streams = 3usize;
    let scenes: Vec<Scene> = (0..n_streams)
        .map(|s| Scene::synthetic(&format!("af{s}"), 2, 200 + s as u64))
        .collect();
    let mut sessions: Vec<_> =
        (0..n_streams).map(|i| engine.new_session(i)).collect();
    let imgs: Vec<TensorF> =
        scenes.iter().map(|sc| sc.normalized_image(0)).collect();
    let frames: Vec<(&TensorF, Mat4)> = imgs
        .iter()
        .zip(&scenes)
        .map(|(img, sc)| (img, sc.poses[0]))
        .collect();
    // warm-up round end to end (queue, extern pool, arena freelists)
    {
        let round = engine.begin_round(&frames).unwrap();
        let mut sess: Vec<&mut _> = sessions.iter_mut().collect();
        engine.finish_round(round, &mut sess).unwrap();
    }
    let imgs1: Vec<TensorF> =
        scenes.iter().map(|sc| sc.normalized_image(1)).collect();
    let frames1: Vec<(&TensorF, Mat4)> = imgs1
        .iter()
        .zip(&scenes)
        .map(|(img, sc)| (img, sc.poses[1]))
        .collect();
    reset_thread_counters();
    let round = engine.begin_round(&frames1).unwrap();
    assert_eq!(
        thread_large_allocs(),
        n_streams as u64,
        "begin_round must allocate exactly one quantized payload per \
         stream; anything more is a submit-path copy"
    );
    let mut sess: Vec<&mut _> = sessions.iter_mut().collect();
    engine.finish_round(round, &mut sess).unwrap();
}

#[test]
fn run_pipelined_submits_payloads_without_copying() {
    // whole-stack accounting: a pipelined window pushes every HW
    // segment's inputs through the ownership-transferring queue. The
    // per-round serving-thread behaviour is pinned by the begin_round
    // test above; here we pin that the queue saw real payload traffic —
    // bytes that under the PR-4 scheme were all deep-copied at submit
    // (bit-exactness of the same window is pinned in tests/server.rs)
    let n_frames = 3usize;
    let n_streams = 2usize;
    let scenes: Vec<Scene> = (0..n_streams)
        .map(|s| Scene::synthetic(&format!("afp{s}"), n_frames, 90 + s as u64))
        .collect();
    let backend = Arc::new(RefBackend::synthetic(11));
    let qp = Arc::clone(backend.qp());
    let mut server = StreamServer::new(
        Arc::clone(&backend) as Arc<dyn HwBackend>,
        qp,
        PipelineOptions::default(),
    )
    .unwrap();
    let streams: Vec<usize> =
        (0..n_streams).map(|_| server.open_stream()).collect();
    let imgs: Vec<Vec<TensorF>> = (0..n_frames)
        .map(|i| scenes.iter().map(|sc| sc.normalized_image(i)).collect())
        .collect();
    let rounds: Vec<Vec<(usize, &TensorF, &Mat4)>> = (0..n_frames)
        .map(|i| {
            streams
                .iter()
                .map(|&s| (s, &imgs[i][s], &scenes[s].poses[i]))
                .collect()
        })
        .collect();
    let before = backend.submit_payload_bytes();
    server.run_pipelined(&rounds, 2).unwrap();
    let moved = backend.submit_payload_bytes() - before;
    // every queued HW call of every round moved its inputs as handles;
    // at minimum the N quantized images per round crossed the queue
    let img_bytes = (3 * fadec::config::IMG_H * fadec::config::IMG_W * 2) as u64;
    assert!(
        moved >= (n_frames * n_streams) as u64 * img_bytes,
        "submit queue saw too little traffic: {moved} bytes"
    );
}
