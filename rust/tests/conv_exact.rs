//! Bit-exactness property tests for the packed conv kernels (PR 2).
//!
//! The fast interior/border kernels must equal the original guarded
//! scalar loops (`conv2d_q_ref` / `conv2d_dw_q_ref` / `conv2d_ref` /
//! `conv2d_dw_ref`) on every output element — that is the whole point of
//! the quantized mirrors. Randomized shapes, strides, exponents and
//! thread counts via the repo's hand-rolled seeded PRNG (`util::Rng`;
//! no proptest dependency), with stride-2 and k=1 edge cases always in
//! the pool.

use fadec::ops::{
    conv2d_dw_packed, conv2d_dw_q_packed, conv2d_dw_q_ref, conv2d_dw_ref,
    conv2d_packed, conv2d_q_packed, conv2d_q_ref, conv2d_ref, Arena,
    PackedFConv, PackedQConv,
};
use fadec::quant::QTensor;
use fadec::tensor::{Tensor, TensorF, TensorI32, TensorI8};
use fadec::util::Rng;

const KERNELS: [usize; 3] = [1, 3, 5];
const STRIDES: [usize; 2] = [1, 2];

/// int8 weights with a real zero fraction, so the zero-tap pre-skip path
/// is always exercised.
fn rand_w_i8(rng: &mut Rng, n: usize) -> Vec<i8> {
    (0..n)
        .map(|_| {
            if rng.below(4) == 0 { 0i8 } else { rng.range_i64(-127, 127) as i8 }
        })
        .collect()
}

fn rand_x_i16(rng: &mut Rng, n: usize) -> Vec<i16> {
    (0..n).map(|_| rng.range_i64(-4000, 4000) as i16).collect()
}

#[test]
fn dense_quant_matches_reference_over_random_shapes() {
    let mut rng = Rng::new(0xC0FFEE);
    for trial in 0..120 {
        let k = KERNELS[rng.below(3) as usize];
        let stride = STRIDES[rng.below(2) as usize];
        let ic = rng.range_i64(1, 6) as usize;
        let oc = rng.range_i64(1, 6) as usize;
        let h = rng.range_i64(1, 10) as usize;
        let w = rng.range_i64(1, 10) as usize;
        let in_exp = rng.range_i64(4, 12) as i32;
        let out_exp = rng.range_i64(4, 12) as i32;
        let s_q = rng.range_i64(1, 127) as i32;
        let r = rng.range_i64(-2, 14) as i32;
        let relu = rng.below(2) == 0;

        let x = QTensor {
            t: Tensor::from_vec(&[1, ic, h, w], rand_x_i16(&mut rng, ic * h * w)),
            exp: in_exp,
        };
        let wt = TensorI8::from_vec(
            &[oc, ic, k, k],
            rand_w_i8(&mut rng, oc * ic * k * k),
        );
        let b = TensorI32::from_vec(
            &[oc],
            (0..oc).map(|_| rng.range_i64(-1024, 1024) as i32).collect(),
        );

        let expect = conv2d_q_ref(&x, &wt, &b, stride, s_q, r, relu, out_exp);
        let pw = PackedQConv::pack_dense(&wt);
        let threads = rng.range_i64(1, 4) as usize;
        let mut arena = Arena::with_threads(threads);
        let got = conv2d_q_packed(
            &x, &pw, b.data(), stride, s_q, r, relu, out_exp, &mut arena,
        );
        assert_eq!(got.exp, expect.exp);
        assert_eq!(got.t.shape(), expect.t.shape());
        assert_eq!(
            got.t.data(),
            expect.t.data(),
            "trial {trial}: ic={ic} oc={oc} h={h} w={w} k={k} s={stride} \
             r={r} s_q={s_q} relu={relu} threads={threads}"
        );
    }
}

#[test]
fn depthwise_quant_matches_reference_over_random_shapes() {
    let mut rng = Rng::new(0xDEC0DE);
    for trial in 0..120 {
        let k = KERNELS[rng.below(3) as usize];
        let stride = STRIDES[rng.below(2) as usize];
        let c = rng.range_i64(1, 8) as usize;
        let h = rng.range_i64(1, 10) as usize;
        let w = rng.range_i64(1, 10) as usize;
        let s_q = rng.range_i64(1, 127) as i32;
        let r = rng.range_i64(-2, 14) as i32;
        let relu = rng.below(2) == 0;

        let x = QTensor {
            t: Tensor::from_vec(&[1, c, h, w], rand_x_i16(&mut rng, c * h * w)),
            exp: 8,
        };
        let wt =
            TensorI8::from_vec(&[c, 1, k, k], rand_w_i8(&mut rng, c * k * k));
        let b = TensorI32::from_vec(
            &[c],
            (0..c).map(|_| rng.range_i64(-1024, 1024) as i32).collect(),
        );

        let expect = conv2d_dw_q_ref(&x, &wt, &b, stride, s_q, r, relu, 8);
        let pw = PackedQConv::pack_depthwise(&wt);
        let threads = rng.range_i64(1, 4) as usize;
        let mut arena = Arena::with_threads(threads);
        let got = conv2d_dw_q_packed(
            &x, &pw, b.data(), stride, s_q, r, relu, 8, &mut arena,
        );
        assert_eq!(
            got.t.data(),
            expect.t.data(),
            "trial {trial}: c={c} h={h} w={w} k={k} s={stride} threads={threads}"
        );
    }
}

#[test]
fn float_kernels_match_reference_bitwise() {
    // same per-element summation order -> float results are bit-identical,
    // not merely close
    let mut rng = Rng::new(0xF10A7);
    for trial in 0..80 {
        let k = KERNELS[rng.below(3) as usize];
        let stride = STRIDES[rng.below(2) as usize];
        let ic = rng.range_i64(1, 5) as usize;
        let oc = rng.range_i64(1, 5) as usize;
        let h = rng.range_i64(1, 9) as usize;
        let w = rng.range_i64(1, 9) as usize;

        let x = TensorF::from_vec(
            &[1, ic, h, w],
            (0..ic * h * w).map(|_| rng.normal_f32()).collect(),
        );
        let wt = TensorF::from_vec(
            &[oc, ic, k, k],
            (0..oc * ic * k * k).map(|_| rng.normal_f32()).collect(),
        );
        let b: Vec<f32> = (0..oc).map(|_| rng.normal_f32()).collect();

        let expect = conv2d_ref(&x, &wt, &b, stride);
        let pw = PackedFConv::pack_dense(&wt);
        let mut arena = Arena::with_threads(rng.range_i64(1, 3) as usize);
        let got = conv2d_packed(&x, &pw, &b, stride, &mut arena);
        assert_eq!(got.data(), expect.data(), "dense trial {trial} k={k}");

        // depthwise on the same spatial shape
        let xdw = TensorF::from_vec(
            &[1, oc, h, w],
            (0..oc * h * w).map(|_| rng.normal_f32()).collect(),
        );
        let wdw = TensorF::from_vec(
            &[oc, 1, k, k],
            (0..oc * k * k).map(|_| rng.normal_f32()).collect(),
        );
        let expect = conv2d_dw_ref(&xdw, &wdw, &b, stride);
        let pdw = PackedFConv::pack_depthwise(&wdw);
        let got = conv2d_dw_packed(&xdw, &pdw, &b, stride, &mut arena);
        assert_eq!(got.data(), expect.data(), "dw trial {trial} k={k}");
    }
}

#[test]
fn pipeline_shape_all_thread_counts_agree() {
    // the acceptance shape (1/2-scale CVE-like 3x3) across 1..6 workers,
    // including counts that do not divide the channel count evenly
    let mut rng = Rng::new(7);
    let x = QTensor {
        t: Tensor::from_vec(&[1, 64, 32, 48], rand_x_i16(&mut rng, 64 * 32 * 48)),
        exp: 8,
    };
    let wt = TensorI8::from_vec(&[32, 64, 3, 3], rand_w_i8(&mut rng, 32 * 64 * 9));
    let b = TensorI32::from_vec(
        &[32],
        (0..32).map(|_| rng.range_i64(-512, 512) as i32).collect(),
    );
    let expect = conv2d_q_ref(&x, &wt, &b, 1, 17, 12, true, 8);
    let pw = PackedQConv::pack_dense(&wt);
    for threads in 1..=6 {
        let mut arena = Arena::with_threads(threads);
        let got =
            conv2d_q_packed(&x, &pw, b.data(), 1, 17, 12, true, 8, &mut arena);
        assert_eq!(got.t.data(), expect.t.data(), "threads={threads}");
        // arena reuse across calls stays exact too
        let again =
            conv2d_q_packed(&x, &pw, b.data(), 1, 17, 12, true, 8, &mut arena);
        assert_eq!(again.t.data(), expect.t.data(), "reused arena t={threads}");
        arena.recycle_q(got);
        let recycled =
            conv2d_q_packed(&x, &pw, b.data(), 1, 17, 12, true, 8, &mut arena);
        assert_eq!(recycled.t.data(), expect.t.data(), "recycled t={threads}");
    }
}

#[test]
fn stride2_and_k1_edges_explicitly() {
    // k=1 never has a border; stride-2 exercises the strided interior;
    // the 64x96 case clears the parallel threshold so the threaded path
    // runs with a non-dividing channel count
    let mut rng = Rng::new(11);
    for &(k, s, h, w) in
        &[(1usize, 2usize, 5usize, 4usize), (1, 1, 1, 1), (3, 2, 2, 2),
          (5, 2, 3, 7), (5, 1, 4, 4), (3, 2, 64, 96)]
    {
        let ic = 4;
        let oc = 16;
        let x = QTensor {
            t: Tensor::from_vec(&[1, ic, h, w], rand_x_i16(&mut rng, ic * h * w)),
            exp: 8,
        };
        let wt =
            TensorI8::from_vec(&[oc, ic, k, k], rand_w_i8(&mut rng, oc * ic * k * k));
        let b = TensorI32::from_vec(&[oc], vec![5; oc]);
        let expect = conv2d_q_ref(&x, &wt, &b, s, 9, 6, false, 8);
        let pw = PackedQConv::pack_dense(&wt);
        let mut arena = Arena::with_threads(2);
        let got = conv2d_q_packed(&x, &pw, b.data(), s, 9, 6, false, 8, &mut arena);
        assert_eq!(got.t.shape(), expect.t.shape(), "k={k} s={s} h={h} w={w}");
        assert_eq!(got.t.data(), expect.t.data(), "k={k} s={s} h={h} w={w}");
    }
}
