//! Copy-on-write data-plane property tests (PR 5): tensor clones are
//! O(1) handles that share storage until mutation, CoW stays correct
//! across thread hand-offs, arena recycling never resurrects aliased
//! storage, and the serving stack's sharing points (keyframe buffer,
//! session depth) really do alias one payload.

use fadec::coordinator::{Coordinator, PipelineOptions};
use fadec::data::dataset::Scene;
use fadec::kb::KeyframeBuffer;
use fadec::ops::Arena;
use fadec::poses::Mat4;
use fadec::quant::QTensor;
use fadec::runtime::{HwBackend, RefBackend};
use fadec::tensor::{Tensor, TensorF, TensorI16};
use fadec::util::Rng;

#[test]
fn qtensor_and_tensorf_clones_share_until_mutation() {
    // property over random shapes: clone == alias; first mutation of
    // either side diverges exactly that side and never the other
    let mut rng = Rng::new(41);
    for _ in 0..50 {
        let n = rng.range_i64(1, 200) as usize;
        let qa = QTensor {
            t: TensorI16::from_vec(
                &[1, 1, 1, n],
                (0..n).map(|_| rng.range_i64(-100, 100) as i16).collect(),
            ),
            exp: rng.range_i64(0, 12) as i32,
        };
        let mut qb = qa.clone();
        assert!(qa.t.shares_payload_with(&qb.t));
        assert_eq!(qa.exp, qb.exp);
        let before: Vec<i16> = qa.t.data().to_vec();
        let i = rng.below(n as u64) as usize;
        let bumped = qa.t.data()[i].wrapping_add(1);
        qb.t.data_mut()[i] = bumped;
        assert!(!qa.t.shares_payload_with(&qb.t), "mutation un-shares");
        assert_eq!(qa.t.data(), &before[..], "original perturbed by CoW");
        assert_ne!(qa.t.data()[i], qb.t.data()[i]);

        let fa = TensorF::from_vec(
            &[1, 1, 1, n],
            (0..n).map(|_| rng.range_f32(-2.0, 2.0)).collect(),
        );
        let mut fb = fa.clone();
        assert!(fa.shares_payload_with(&fb));
        fb.data_mut()[i] += 1.0;
        assert!(!fa.shares_payload_with(&fb));
        assert!((fb.data()[i] - fa.data()[i] - 1.0).abs() < 1e-6);
    }
}

#[test]
fn make_mut_after_cross_thread_handoff_is_race_free() {
    // hand clones of one payload to several threads; each mutates its
    // own handle (triggering CoW on first write) while the original is
    // concurrently read — every thread must see its own divergent copy
    // and the original must come back bit-identical
    let n = 4096usize;
    let base: Vec<i16> = (0..n).map(|i| (i as i16).wrapping_mul(3)).collect();
    let original = TensorI16::from_vec(&[1, 1, 64, 64], base.clone());
    std::thread::scope(|s| {
        for t in 0..4i16 {
            let mut mine = original.clone();
            s.spawn(move || {
                for v in mine.data_mut() {
                    *v = v.wrapping_add(t + 1);
                }
                for (i, &v) in mine.data().iter().enumerate() {
                    assert_eq!(
                        v,
                        (i as i16).wrapping_mul(3).wrapping_add(t + 1),
                        "thread {t} sees its own copy"
                    );
                }
            });
        }
        // concurrent reader of the shared payload
        s.spawn(|| {
            let reader = original.clone();
            assert_eq!(reader.data()[17], 51);
        });
    });
    assert_eq!(original.data(), &base[..], "hand-offs never wrote through");
    assert!(original.is_unique(), "every thread's handle retired");
}

#[test]
fn arena_recycling_never_resurrects_an_aliased_buffer() {
    let mut arena = Arena::new();
    // checkout -> tensor -> alias -> recycle one handle
    let payload = arena.take_i16(32);
    let q = QTensor { t: Tensor::from_vec(&[1, 1, 4, 8], payload), exp: 5 };
    let live = q.clone();
    let before: Vec<i16> = live.t.data().to_vec();
    arena.recycle_q(q);
    assert_eq!(arena.free_buffers(), 0, "aliased payload must not park");
    // hammer the freelist: nothing we take and scribble on may alias
    // the live handle
    for round in 0..8 {
        let mut v = arena.take_i16(32);
        assert_ne!(
            v.as_ptr(),
            live.t.data().as_ptr(),
            "round {round}: freelist handed out an aliased buffer"
        );
        v.iter_mut().for_each(|x| *x = -77);
        arena.recycle_i16(v);
    }
    assert_eq!(live.t.data(), &before[..]);
    // the last handle parks the payload for real reuse (the loop's
    // scratch buffer is the other parked entry)
    arena.recycle_q(live);
    assert_eq!(arena.free_buffers(), 2);
}

#[test]
fn keyframe_buffer_entries_alias_the_producer_payload() {
    let mut kb: KeyframeBuffer<QTensor> = KeyframeBuffer::with_policy(2, 0.1);
    let feat = QTensor {
        t: TensorI16::from_vec(&[1, 1, 2, 2], vec![1, 2, 3, 4]),
        exp: 7,
    };
    let mut pose = Mat4::identity();
    assert!(kb.maybe_insert(pose, feat.clone()));
    pose.0[3] = 1.0;
    assert!(kb.maybe_insert(pose, feat.clone()));
    // both stored keyframes and the producer share one payload
    let snap = kb.snapshot();
    assert!(snap[0].1.t.shares_payload_with(&feat.t));
    assert!(snap[1].1.t.shares_payload_with(&feat.t));
    // a consumer mutating its snapshot copy never corrupts the buffer
    let mut mine = snap[0].1.clone();
    mine.t.data_mut()[0] = -1;
    assert_eq!(kb.contents()[0].1.t.data(), &[1, 2, 3, 4]);
}

#[test]
fn pipelined_outputs_share_depth_with_the_session_yet_stay_immutable() {
    // end-to-end: the frame output's depth and the session's depth_full
    // are the same payload (commit clones a handle, not 150 KB of
    // floats), and mutating the caller's output CoWs away from the
    // session - the next frame's hidden-state correction still reads
    // the undisturbed depth (bit-identical to an untouched run)
    let scene = Scene::synthetic("cow-e2e", 3, 14);
    let run = |mutate: bool| -> Vec<TensorF> {
        let mut coord =
            Coordinator::on_ref_backend(77, PipelineOptions::default()).unwrap();
        (0..3)
            .map(|i| {
                let img = scene.normalized_image(i);
                let mut out = coord.step(&img, &scene.poses[i]).unwrap();
                assert!(
                    out.depth
                        .shares_payload_with(coord.session().last_depth()),
                    "frame {i}: output depth is a handle onto session state"
                );
                if mutate {
                    // caller scribbles on its copy; the session must not
                    // see it (CoW isolates the mutation)
                    out.depth.data_mut()[0] = -1234.5;
                    assert!(!out
                        .depth
                        .shares_payload_with(coord.session().last_depth()));
                }
                coord.session().last_depth().clone()
            })
            .collect()
    };
    let clean = run(false);
    let mutated = run(true);
    for (i, (a, b)) in clean.iter().zip(&mutated).enumerate() {
        assert_eq!(
            a.data(),
            b.data(),
            "frame {i}: caller-side mutation leaked into the session"
        );
    }
}

#[test]
fn submitted_inputs_survive_aggressive_caller_reuse() {
    // ownership transfer + CoW: after submitting, the caller may mutate
    // or drop its remaining handles freely without perturbing the
    // queued job's inputs — outputs must equal the blocking path's
    let be = RefBackend::synthetic(7);
    let id = be.resolve("fe_fs").unwrap();
    let mut rng = Rng::new(3);
    let (h, w) = (fadec::config::IMG_H, fadec::config::IMG_W);
    let img = TensorF::from_vec(
        &[1, 3, h, w],
        (0..3 * h * w).map(|_| rng.range_f32(-2.0, 2.0)).collect(),
    );
    let img_q = fadec::quant::quantize_tensor(&img, be.qp().aexp("image"));
    let want = be.run(id, &[&img_q]).unwrap();
    let mut kept = img_q.clone();
    let handle = be.submit(id, vec![img_q]).unwrap();
    // scribble on the caller's handle while the job is in flight
    kept.t.data_mut().iter_mut().for_each(|v| *v = 0);
    let got = handle.wait().unwrap();
    for (a, b) in got.iter().zip(&want) {
        assert_eq!(a.t.data(), b.t.data(), "caller reuse corrupted the job");
        assert_eq!(a.exp, b.exp);
    }
}
