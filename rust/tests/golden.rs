//! Golden integration tests: the Rust side against the python hybrid
//! reference (`artifacts/golden/frame*.bin`, emitted by aot.py).
//!
//! Three layers of pinning:
//!  1. **Segment-level, bit-exact**: every AOT artifact executed via PJRT
//!     on the golden inputs must reproduce the golden outputs *exactly*
//!     (the HW side is pure integer arithmetic).
//!  2. **Rust mirror, bit-exact**: `QuantModel`'s segment functions must
//!     match the same goldens (they implement the same integer contract).
//!  3. **Pipeline-level, tolerance**: full sequences through the
//!     coordinator / QuantModel track the golden depths (float software
//!     ops differ across languages at the ulp level, so requantized
//!     boundaries may flip the odd LSB).
//!
//! Requires `make artifacts` and a real xla runtime, so every test here
//! is `#[ignore]`d — tier-1 `cargo test` passes from a clean checkout
//! (artifact-free coverage lives in `server.rs` on the RefBackend). Run
//! these with `cargo test --test golden -- --ignored` after the build.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use fadec::config;
use fadec::coordinator::PipelineOptions;
use fadec::data::manifest::Manifest;
use fadec::data::tlv::TlvFile;
use fadec::model::{QuantModel, QuantParams};
use fadec::quant::QTensor;
use fadec::runtime::{HwBackend, HwRuntime};
use fadec::tensor::{Tensor, TensorF};

fn artifacts() -> PathBuf {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    root.join("artifacts")
}

fn load_all() -> (Manifest, Arc<QuantParams>, Vec<TlvFile>) {
    let art = artifacts();
    let manifest = Manifest::load(&art.join("manifest.txt")).expect("manifest");
    let qp = Arc::new(
        QuantParams::load(&art.join("qparams.bin"), &manifest).expect("qparams"),
    );
    qp.validate().expect("bias exponent contract");
    let mut frames = Vec::new();
    for i in 0.. {
        let p = art.join("golden").join(format!("frame{i}.bin"));
        if !p.is_file() {
            break;
        }
        frames.push(TlvFile::load(&p).expect("golden frame"));
    }
    assert!(frames.len() >= 2, "need at least 2 golden frames");
    (manifest, qp, frames)
}

/// Golden key for a (segment, input-name) pair at frame `fi`.
fn golden_input_key(seg: &str, input: &str, fi: usize) -> (String, usize) {
    // cross-frame state comes from the previous frame's trace
    match input {
        "c_q" => ("cnew_q".to_string(), fi.wrapping_sub(1)),
        "ln_c_q" => ("lnc_q".to_string(), fi),
        name if name.starts_with("xln_b") => {
            let b: usize = seg.split("_b").nth(1).unwrap()[..1].parse().unwrap();
            if let Some(i) = seg.split("mid").nth(1) {
                let i: usize = i.parse().unwrap();
                (format!("xln_b{b}_{}", i - 1), fi)
            } else {
                (format!("xln_b{b}_last"), fi)
            }
        }
        "upf_q" | "upd_q" => {
            let b: usize = seg.split("_b").nth(1).unwrap()[..1].parse().unwrap();
            (format!("{}{}_q", &input[..3], b), fi)
        }
        other => (other.to_string(), fi),
    }
}

/// Golden key for a (segment, output-name) pair.
fn golden_output_key(seg: &str, output: &str) -> String {
    if let Some(rest) = output.strip_prefix("x_b") {
        let b = &rest[..1];
        if let Some(i) = seg.split("mid").nth(1) {
            format!("x_b{b}_mid{i}")
        } else {
            format!("x_b{b}_entry")
        }
    } else {
        output.to_string()
    }
}

fn golden_qtensor(
    frames: &[TlvFile],
    key: &(String, usize),
    shape: &[usize],
    exp: i32,
) -> Option<QTensor> {
    if key.1 == usize::MAX {
        return None; // frame -1: zero state
    }
    let entry = frames.get(key.1)?.entries.get(&key.0)?;
    let t = entry.as_i16().ok()?;
    Some(QTensor { t: Tensor::from_vec(shape, t.data().to_vec()), exp })
}

#[test]
#[ignore = "requires `make artifacts` + the real xla runtime"]
fn segments_bit_exact_via_pjrt_and_rust_mirror() {
    let (manifest, qp, frames) = load_all();
    let hw = HwRuntime::load(&artifacts(), &manifest).expect("runtime");
    let qm = QuantModel::new(Arc::clone(&qp));
    let mut checked = 0usize;
    for (fi, frame) in frames.iter().enumerate() {
        // frame 0 has kf_count == 0 -> cost volume is all zeros, which the
        // python trace also reflects; all frames are equally valid here.
        for seg in &manifest.segments {
            let mut inputs = Vec::new();
            let mut ok = true;
            for d in &seg.inputs {
                let key = golden_input_key(&seg.name, &d.name, fi);
                let q = if key.1 == usize::MAX || (d.name == "c_q" && fi == 0) {
                    Some(QTensor::zeros(&d.shape, d.exp))
                } else {
                    golden_qtensor(&frames, &key, &d.shape, d.exp)
                };
                match q {
                    Some(q) => inputs.push(q),
                    None => {
                        ok = false;
                        break;
                    }
                }
            }
            if !ok {
                continue;
            }
            let refs: Vec<&QTensor> = inputs.iter().collect();
            let outs = hw.run_named(&seg.name, &refs).expect("segment exec");
            // 2) the Rust integer mirror on the same inputs
            let mirror: Vec<QTensor> = match seg.name.as_str() {
                "fe_fs" => qm.seg_fe_fs(&inputs[0]),
                "cve" => qm.seg_cve(&inputs[0], &refs[1..]),
                "cl_gates" => vec![qm.seg_cl_gates(&inputs[0], &inputs[1])],
                "cl_state" => {
                    let (c, o) = qm.seg_cl_state(&inputs[0], &inputs[1]);
                    vec![c, o]
                }
                "cl_out" => vec![qm.seg_cl_out(&inputs[0], &inputs[1])],
                name if name.contains("_entry") => {
                    let b: usize =
                        name.split("_b").nth(1).unwrap()[..1].parse().unwrap();
                    vec![qm.seg_cvd_entry(b, &refs)]
                }
                name if name.contains("_mid") => {
                    let b: usize =
                        name.split("_b").nth(1).unwrap()[..1].parse().unwrap();
                    let i: usize = name.split("mid").nth(1).unwrap().parse().unwrap();
                    vec![qm.seg_cvd_mid(b, i, &inputs[0])]
                }
                name if name.contains("_head") => {
                    let b: usize =
                        name.split("_b").nth(1).unwrap()[..1].parse().unwrap();
                    vec![qm.seg_cvd_head(b, &inputs[0])]
                }
                other => panic!("unknown segment {other}"),
            };
            for (oi, d) in seg.outputs.iter().enumerate() {
                let key = golden_output_key(&seg.name, &d.name);
                let Some(gold) = frame.entries.get(&key) else {
                    panic!("golden missing output {key} for {}", seg.name);
                };
                let gold = gold.as_i16().unwrap();
                assert_eq!(
                    outs[oi].t.data(),
                    gold.data(),
                    "PJRT output {} of segment {} (frame {fi}) != golden",
                    d.name,
                    seg.name
                );
                assert_eq!(
                    mirror[oi].t.data(),
                    gold.data(),
                    "Rust mirror output {} of segment {} (frame {fi}) != golden",
                    d.name,
                    seg.name
                );
                checked += 1;
            }
        }
    }
    assert!(checked > 50, "only {checked} segment outputs checked");
    println!("verified {checked} segment outputs bit-exact (PJRT + mirror)");
}

fn load_scene_frames(n: usize) -> (Vec<TensorF>, Vec<fadec::poses::Mat4>, Vec<TensorF>) {
    let ds = fadec::data::Dataset::open(&artifacts().join("dataset")).unwrap();
    let scene = ds.load_scene("chess-01").unwrap();
    let imgs = (0..n).map(|i| scene.normalized_image(i)).collect();
    let poses = scene.poses[..n].to_vec();
    let gts = (0..n).map(|i| scene.depth_tensor(i)).collect();
    (imgs, poses, gts)
}

/// Max |a-b| and mismatch fraction between two i16 tensors.
fn i16_diff(a: &[i16], b: &[i16]) -> (i32, f64) {
    let mut maxd = 0i32;
    let mut n_bad = 0usize;
    for (x, y) in a.iter().zip(b) {
        let d = (*x as i32 - *y as i32).abs();
        maxd = maxd.max(d);
        if d > 2 {
            n_bad += 1;
        }
    }
    (maxd, n_bad as f64 / a.len() as f64)
}

#[test]
#[ignore = "requires `make artifacts` + the real xla runtime"]
fn coordinator_tracks_python_golden_sequence() {
    let (manifest, qp, frames) = load_all();
    let mut coord = fadec::coordinator::Coordinator::new(
        &artifacts(),
        &manifest,
        Arc::clone(&qp),
        PipelineOptions::default(),
    )
    .expect("coordinator");
    let n = frames.len();
    let (imgs, poses, _) = load_scene_frames(n);
    for fi in 0..n {
        let out = coord.step_traced(&imgs[fi], &poses[fi]).expect("step");
        let trace = out.trace.unwrap();
        // image quantization must be bit-exact (pure integer rounding)
        let gold_img = frames[fi].entries["image_q"].as_i16().unwrap();
        assert_eq!(trace["image_q"].t.data(), gold_img.data(), "frame {fi}");
        // boundary tensors: float SW ops differ at ulp level across
        // languages, so allow rare small LSB flips
        for key in ["cost_q", "e4_q", "gates_q", "hnew_q", "head4_q"] {
            let gold = frames[fi].entries[key].as_i16().unwrap();
            let got = &trace[key];
            let (maxd, frac_bad) = i16_diff(got.t.data(), gold.data());
            assert!(
                frac_bad < 0.03,
                "frame {fi} {key}: {:.2}% elements differ by >2 LSB (max {maxd})",
                frac_bad * 100.0
            );
        }
        // final depth in metres
        let gold_depth = frames[fi].entries["depth_out"].as_f32().unwrap();
        let mut max_abs = 0.0f32;
        for (a, b) in out.depth.data().iter().zip(gold_depth.data()) {
            max_abs = max_abs.max((a - b).abs());
        }
        assert!(
            max_abs < 0.08,
            "frame {fi}: depth deviates from python golden by {max_abs} m"
        );
    }
}

#[test]
#[ignore = "requires `make artifacts` + the real xla runtime"]
fn coordinator_equals_rust_ptq_mirror_exactly() {
    // The coordinator (PJRT artifacts + SW ops) and the QuantModel (pure
    // Rust mirror) implement the same integer contract over the same SW
    // float ops — their outputs must be identical bit-for-bit.
    let (manifest, qp, _) = load_all();
    let mut coord = fadec::coordinator::Coordinator::new(
        &artifacts(),
        &manifest,
        Arc::clone(&qp),
        PipelineOptions::default(),
    )
    .unwrap();
    let qm = QuantModel::new(Arc::clone(&qp));
    let mut kb = fadec::kb::KeyframeBuffer::new();
    let mut st = fadec::model::QuantState::zero(&qp);
    let (imgs, poses, _) = load_scene_frames(4);
    for fi in 0..imgs.len() {
        let co = coord.step(&imgs[fi], &poses[fi]).unwrap();
        let (depth, f_half) = qm.step(&imgs[fi], &poses[fi], &kb, &mut st);
        kb.maybe_insert(poses[fi], f_half);
        assert_eq!(
            co.depth.data(),
            depth.data(),
            "frame {fi}: coordinator and PTQ mirror disagree"
        );
    }
}

#[test]
#[ignore = "requires `make artifacts` + the real xla runtime"]
fn overlap_ablation_is_bit_identical() {
    // Task-level parallelization must not change results, only timing.
    let (manifest, qp, _) = load_all();
    let mk = |overlap: bool| {
        fadec::coordinator::Coordinator::new(
            &artifacts(),
            &manifest,
            Arc::clone(&qp),
            PipelineOptions { overlap, sw_threads: 2, ..Default::default() },
        )
        .unwrap()
    };
    let mut with = mk(true);
    let mut without = mk(false);
    let (imgs, poses, _) = load_scene_frames(3);
    for fi in 0..imgs.len() {
        let a = with.step(&imgs[fi], &poses[fi]).unwrap();
        let b = without.step(&imgs[fi], &poses[fi]).unwrap();
        assert_eq!(a.depth.data(), b.depth.data(), "frame {fi}");
    }
}

#[test]
#[ignore = "requires `make artifacts` + the real xla runtime"]
fn float_model_tracks_python_float_tape() {
    // Layer-by-layer comparison of the Rust float model against the jnp
    // float activations of frame 0 (tolerances absorb conv-order ulps).
    let art = artifacts();
    let fp = fadec::model::FloatParams::load(&art.join("weights.bin")).unwrap();
    let model = fadec::model::FloatModel::new(&fp);
    let tape = TlvFile::load(&art.join("golden").join("float_tape0.bin")).unwrap();
    let (imgs, _, _) = load_scene_frames(1);
    let feats = model.fe_fs(&imgs[0]);
    for (i, f) in feats.iter().enumerate() {
        let name = if i == 0 {
            "fs.smooth0".to_string()
        } else if i < 4 {
            format!("fs.smooth{i}")
        } else {
            "fs.lat4".to_string()
        };
        let gold = tape.f32(&name).unwrap();
        let mut max_abs = 0.0f32;
        for (a, b) in f.data().iter().zip(gold.data()) {
            max_abs = max_abs.max((a - b).abs());
        }
        let scale = gold.data().iter().fold(0f32, |m, v| m.max(v.abs()));
        assert!(
            max_abs <= 2e-3 * scale.max(1.0),
            "pyramid level {i}: max abs diff {max_abs} (scale {scale})"
        );
    }
    // full step: depth within loose tolerance of the python float path
    let gold_full = tape.f32("cvd.b4.head").unwrap();
    let mut state = fadec::model::FloatState::zero();
    let kb = fadec::kb::KeyframeBuffer::new();
    let (_, poses, _) = load_scene_frames(1);
    let (depth, _) = model.step(&imgs[0], &poses[0], &kb, &mut state);
    // compare in depth space at the head resolution via the same mapping
    let mean_head: f32 =
        gold_full.data().iter().sum::<f32>() / gold_full.len() as f32;
    let mean_depth: f32 = depth.data().iter().sum::<f32>() / depth.len() as f32;
    let approx = config::depth_from_sigmoid(mean_head);
    assert!(
        (mean_depth - approx).abs() < 1.0,
        "float pipeline depth mean {mean_depth} vs python-derived {approx}"
    );
}
