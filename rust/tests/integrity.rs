//! Data-plane integrity tests (PR 10): the ingestion guard screens
//! every `(img, pose)` capture before it reaches the FSM, and the pins
//! here are the layer's contract:
//!
//! * a guarded **clean** run is bit-identical to an unguarded one —
//!   screening is read-only on the clean path;
//! * each [`GuardPolicy`] disposition behaves exactly as specified
//!   under hand-traceable poison (typed rejection, hold-last-depth
//!   with zero session mutation, sanitize == hand-repaired input);
//! * a stream feeding consecutive poison is quarantined through the
//!   continuous scheduler (downgrade, then shed) while its neighbors
//!   stay bit-identical to solo serving, and the shed checkpoint is
//!   the *pre-poison* state — restorable and resumable bit-exactly;
//! * a NaN-poisoned session can never reach a checkpoint: the store
//!   refuses non-finite session state outright.

use std::path::PathBuf;
use std::sync::Arc;

use fadec::config::{IMG_H, IMG_W};
use fadec::coordinator::{
    is_frame_rejected, ContinuousStream, Coordinator, FaultKind,
    GuardOptions, GuardPolicy, PipelineOptions, SchedulerOptions,
    SessionStore, StreamDisposition, StreamServer,
};
use fadec::data::dataset::Scene;
use fadec::poses::Mat4;
use fadec::runtime::{ChaosSource, ChaosSourceOptions};
use fadec::tensor::TensorF;

const SEED: u64 = 7;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("fadec_integ_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn make_scenes(n_streams: usize, frames: usize, base_seed: u64) -> Vec<Scene> {
    (0..n_streams)
        .map(|s| {
            Scene::synthetic(&format!("sc-{s}"), frames, base_seed + s as u64)
        })
        .collect()
}

fn render(scenes: &[Scene], frames: usize) -> Vec<Vec<TensorF>> {
    scenes
        .iter()
        .map(|sc| (0..frames).map(|i| sc.normalized_image(i)).collect())
        .collect()
}

/// Fault-free single-stream reference on a clean unguarded backend.
fn solo_run(scene: &Scene, n: usize) -> Vec<TensorF> {
    let mut coord =
        Coordinator::on_ref_backend(SEED, PipelineOptions::default()).unwrap();
    (0..n)
        .map(|i| {
            let img = scene.normalized_image(i);
            coord.step(&img, &scene.poses[i]).unwrap().depth
        })
        .collect()
}

fn guarded_server(n: usize, opts: GuardOptions) -> StreamServer {
    let mut server = StreamServer::on_ref_backend(
        SEED,
        PipelineOptions { guard: Some(opts), ..Default::default() },
    )
    .unwrap();
    for _ in 0..n {
        server.open_stream();
    }
    server
}

#[test]
fn guarded_clean_serving_is_bit_identical_to_unguarded() {
    let (n, frames) = (3, 4);
    let scenes = make_scenes(n, frames, 210);
    let solo: Vec<Vec<TensorF>> =
        scenes.iter().map(|sc| solo_run(sc, frames)).collect();
    let imgs = render(&scenes, frames);
    let mut plain =
        StreamServer::on_ref_backend(SEED, PipelineOptions::default())
            .unwrap();
    for _ in 0..n {
        plain.open_stream();
    }
    let mut guarded = guarded_server(n, GuardOptions::default());
    for f in 0..frames {
        let inputs: Vec<(usize, &TensorF, &Mat4)> = (0..n)
            .map(|s| (s, &imgs[s][f], &scenes[s].poses[f]))
            .collect();
        let a = plain.run_round(&inputs).unwrap();
        let b = guarded.run_round(&inputs).unwrap();
        for ((sa, oa), (sb, ob)) in a.iter().zip(&b) {
            assert_eq!(sa, sb, "round order must match");
            assert_eq!(
                oa.depth.data(),
                ob.depth.data(),
                "stream {sa} frame {f}: guarded != unguarded"
            );
            assert_eq!(
                oa.depth.data(),
                solo[*sa][f].data(),
                "stream {sa} frame {f}: diverged from solo"
            );
        }
    }
    // screening was read-only: every frame validated, none touched
    let st = guarded.integrity_stats();
    assert_eq!(st.validated, n * frames);
    assert_eq!(st.faulty(), 0);
    assert_eq!(st.screened(), n * frames);
    // the always-on stage spot checks ran, and caught nothing, on both
    let pt = plain.integrity_stats();
    assert!(st.stage_checks > 0, "guarded spot checks ran");
    assert!(pt.stage_checks > 0, "unguarded spot checks ran");
    assert_eq!(st.checksum_mismatches, 0);
    assert_eq!(pt.checksum_mismatches, 0);
    // report gating: a screened frame earns the line, spot checks alone
    // don't
    assert!(guarded.report().contains("integrity:"));
    assert!(!plain.report().contains("integrity:"));
}

#[test]
fn reject_policy_is_typed_and_leaves_the_session_untouched() {
    let frames = 4;
    let scene = &make_scenes(1, frames, 220)[0];
    let solo = solo_run(scene, frames);
    let imgs: Vec<TensorF> =
        (0..frames).map(|i| scene.normalized_image(i)).collect();
    let mut server =
        guarded_server(1, GuardOptions::with_policy(GuardPolicy::RejectFrame));
    for f in 0..2 {
        let out = server.step_stream(0, &imgs[f], &scene.poses[f]).unwrap();
        assert_eq!(out.depth.data(), solo[f].data(), "clean frame {f}");
    }
    let mut bad = imgs[2].clone();
    bad.data_mut()[11] = f32::NAN;
    let err = server.step_stream(0, &bad, &scene.poses[2]).unwrap_err();
    let rej = is_frame_rejected(&err).expect("typed rejection");
    assert_eq!(rej.stream, 0);
    assert_eq!(rej.kind, FaultKind::NonFinitePixel);
    assert!(err.to_string().contains("rejected"), "err: {err}");
    // the rejected frame never entered the FSM: the session is exactly
    // where frame 1 left it, so the clean suffix matches solo
    assert_eq!(server.session(0).frames_done(), 2);
    for f in 2..frames {
        let out = server.step_stream(0, &imgs[f], &scene.poses[f]).unwrap();
        assert_eq!(out.depth.data(), solo[f].data(), "post-reject frame {f}");
    }
    let st = server.integrity_stats();
    assert_eq!(st.rejected, 1);
    assert_eq!(st.validated, frames);
    assert_eq!(st.nonfinite_pixels, 1);
}

#[test]
fn hold_policy_reemits_last_depth_and_forgets_the_frame() {
    let frames = 4;
    let scene = &make_scenes(1, frames, 230)[0];
    let solo = solo_run(scene, frames);
    let imgs: Vec<TensorF> =
        (0..frames).map(|i| scene.normalized_image(i)).collect();
    let mut server = guarded_server(1, GuardOptions::default());
    for f in 0..2 {
        let out = server.step_stream(0, &imgs[f], &scene.poses[f]).unwrap();
        assert_eq!(out.depth.data(), solo[f].data(), "clean frame {f}");
    }
    // poison 1: a NaN pixel — held, previous depth re-emitted
    let mut bad = imgs[2].clone();
    bad.data_mut()[0] = f32::NAN;
    let out = server.step_stream(0, &bad, &scene.poses[2]).unwrap();
    assert_eq!(out.depth.data(), solo[1].data(), "held = previous depth");
    // poison 2: a teleporting pose on a clean image — also held
    let mut jump = scene.poses[2];
    jump.0[3] += 1.0e9;
    let out = server.step_stream(0, &imgs[2], &jump).unwrap();
    assert_eq!(out.depth.data(), solo[1].data(), "held = previous depth");
    // the held frames left no trace: serving the clean suffix now is
    // bit-identical to a run that never saw the poison
    assert_eq!(server.session(0).frames_done(), 2);
    for f in 2..frames {
        let out = server.step_stream(0, &imgs[f], &scene.poses[f]).unwrap();
        assert_eq!(out.depth.data(), solo[f].data(), "post-hold frame {f}");
    }
    let st = server.integrity_stats();
    assert_eq!(st.held, 2);
    assert_eq!(st.validated, frames);
    assert_eq!(st.nonfinite_pixels, 1);
    assert_eq!(st.pose_jumps, 1);
}

#[test]
fn sanitize_policy_matches_a_hand_repaired_run() {
    let frames = 4;
    let scene = &make_scenes(1, frames, 240)[0];
    let imgs: Vec<TensorF> =
        (0..frames).map(|i| scene.normalized_image(i)).collect();
    // poison frame 1: one NaN, two out-of-range pixels
    let mut poisoned = imgs[1].clone();
    poisoned.data_mut()[3] = f32::NAN;
    poisoned.data_mut()[5] = 100.0;
    poisoned.data_mut()[9] = -1.0e9;
    // the guard's repair spec: NaN -> 0, clamp to +-max_abs_pixel
    let mut repaired = imgs[1].clone();
    repaired.data_mut()[3] = 0.0;
    repaired.data_mut()[5] = 8.0;
    repaired.data_mut()[9] = -8.0;
    let mut sanitizing =
        guarded_server(1, GuardOptions::with_policy(GuardPolicy::Sanitize));
    let mut plain =
        StreamServer::on_ref_backend(SEED, PipelineOptions::default())
            .unwrap();
    plain.open_stream();
    for f in 0..frames {
        let fed = if f == 1 { &poisoned } else { &imgs[f] };
        let spec = if f == 1 { &repaired } else { &imgs[f] };
        let got =
            sanitizing.step_stream(0, fed, &scene.poses[f]).unwrap();
        let want = plain.step_stream(0, spec, &scene.poses[f]).unwrap();
        assert_eq!(
            got.depth.data(),
            want.depth.data(),
            "frame {f}: sanitize != hand-repaired input"
        );
    }
    let st = sanitizing.integrity_stats();
    assert_eq!(st.sanitized, 1);
    assert_eq!(st.validated, frames - 1);
    assert_eq!(st.nonfinite_pixels, 1);
    assert_eq!(st.oor_pixels, 2);
}

#[test]
fn chaos_source_poison_is_deterministic_and_heals() {
    // nan_rate 1.0 with heal_after 2 is a fully hand-traceable
    // schedule: frames 0 and 1 are NaN-splatted, everything after is
    // clean — independent of the seed
    let frames = 5;
    let scene = &make_scenes(1, frames, 250)[0];
    let imgs: Vec<TensorF> =
        (0..frames).map(|i| scene.normalized_image(i)).collect();
    let copts = ChaosSourceOptions {
        seed: 5,
        nan_rate: 1.0,
        heal_after: Some(2),
        ..Default::default()
    };
    let drive = || -> (Vec<TensorF>, fadec::metrics::IntegrityStats) {
        let src = ChaosSource::new(copts);
        let mut server = guarded_server(1, GuardOptions::default());
        let mut prev: Option<(TensorF, Mat4)> = None;
        let mut outs = Vec::with_capacity(frames);
        for f in 0..frames {
            let (img, pose) = src.corrupt(
                0,
                f,
                &imgs[f],
                &scene.poses[f],
                prev.as_ref().map(|(i, p)| (i, p)),
            );
            outs.push(server.step_stream(0, &img, &pose).unwrap().depth);
            prev = Some((img, pose));
        }
        assert_eq!(src.faults_injected(), 2, "schedule heals after 2");
        assert_eq!(src.nan_splats_injected(), 2);
        (outs, server.integrity_stats())
    };
    let (a, sa) = drive();
    let (b, sb) = drive();
    for (f, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.data(), y.data(), "frame {f}: runs diverged");
    }
    assert_eq!(sa, sb, "identical accounting across identical runs");
    assert_eq!(sa.held, 2);
    assert_eq!(sa.validated, frames - 2);
    assert!(sa.nonfinite_pixels >= 2, "each splat had >= 1 NaN pixel");
    // the held prefix mutated nothing: the clean suffix is the
    // session's *first* committed frames, bit-identical to a fresh run
    // fed only that suffix
    let mut fresh =
        Coordinator::on_ref_backend(SEED, PipelineOptions::default()).unwrap();
    for f in 2..frames {
        let want = fresh.step(&imgs[f], &scene.poses[f]).unwrap();
        assert_eq!(
            a[f].data(),
            want.depth.data(),
            "frame {f}: poisoned prefix left a trace"
        );
    }
}

#[test]
fn poisoned_stream_is_quarantined_shed_pre_poison_and_neighbors_unharmed() {
    // The tentpole pin. Stream 0 feeds 2 clean frames then 8 all-NaN
    // captures; stream 1 is clean throughout. With the default ladder
    // (quarantine_after = 3, degrade_first) the trace is exact:
    // consecutive-fault streak 3 downgrades stream 0, streak 6 sheds it
    // — after 8 served frames (2 clean + 6 held). Held frames never
    // mutate the session, so the shed checkpoint is the state after
    // frame 1: restorable, and resuming the clean suffix from it is
    // bit-identical to solo serving. Stream 1 must not notice any of it.
    let dir = tmp_dir("quarantine");
    let frames = 6;
    let scenes = make_scenes(2, frames, 260);
    let solo: Vec<Vec<TensorF>> =
        scenes.iter().map(|sc| solo_run(sc, frames)).collect();
    let imgs = render(&scenes, frames);
    let nan_img = imgs[0][2].map(|_| f32::NAN);
    let mut feed0: Vec<(&TensorF, Mat4)> =
        (0..2).map(|i| (&imgs[0][i], scenes[0].poses[i])).collect();
    for _ in 0..8 {
        feed0.push((&nan_img, scenes[0].poses[2]));
    }
    let feed1: Vec<(&TensorF, Mat4)> =
        (0..frames).map(|i| (&imgs[1][i], scenes[1].poses[i])).collect();
    let mut server = guarded_server(2, GuardOptions::default());
    let store = SessionStore::open(
        &dir,
        2,
        server.engine().backend().manifest(),
        server.engine().qp().as_ref(),
    )
    .unwrap();
    server.attach_session_store(store);
    let streams =
        vec![ContinuousStream::new(0, feed0), ContinuousStream::new(1, feed1)];
    let out = server
        .run_continuous(&streams, &SchedulerOptions::default())
        .unwrap();
    assert_eq!(
        out.dispositions,
        vec![
            StreamDisposition::Shed { served: 8 },
            StreamDisposition::Completed,
        ]
    );
    assert_eq!(out.stats.downgraded, 1, "streak 3 downgraded stream 0");
    assert_eq!(out.stats.shed, 1, "streak 6 shed stream 0");
    // stream 0: clean prefix exact, then its frame-1 depth re-emitted
    // for every held capture
    assert_eq!(out.outputs[0].len(), 8);
    for f in 0..2 {
        assert_eq!(out.outputs[0][f].depth.data(), solo[0][f].data());
    }
    for f in 2..8 {
        assert_eq!(
            out.outputs[0][f].depth.data(),
            solo[0][1].data(),
            "held frame {f} re-emits the last committed depth"
        );
    }
    // stream 1 never noticed: bit-identical to solo serving
    assert_eq!(out.outputs[1].len(), frames);
    for f in 0..frames {
        assert_eq!(
            out.outputs[1][f].depth.data(),
            solo[1][f].data(),
            "neighbor frame {f} perturbed by the quarantine"
        );
    }
    let st = server.integrity_stats();
    assert_eq!(st.validated, 2 + frames);
    assert_eq!(st.held, 6);
    assert_eq!(st.quarantined, 1);
    assert_eq!(st.shed, 1);
    assert_eq!(st.nonfinite_pixels, 6 * 3 * IMG_H * IMG_W);
    assert!(server.report().contains("quarantined"));
    // the shed checkpoint is the pre-poison state: frames_done = 2,
    // finite, and resuming the clean suffix from it matches solo
    let qp = Arc::clone(server.engine().qp());
    let store = server.session_store_mut().unwrap();
    assert!(store.has_checkpoint(0), "shed left a checkpoint");
    let mut resumed = store.load(0, &qp).unwrap();
    assert_eq!(resumed.frames_done(), 2, "checkpoint predates the poison");
    assert!(resumed.is_finite());
    for f in 2..frames {
        let got = server
            .engine()
            .step_session(&mut resumed, &imgs[0][f], &scenes[0].poses[f])
            .unwrap();
        assert_eq!(
            got.depth.data(),
            solo[0][f].data(),
            "resumed frame {f} diverged from solo"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn a_poisoned_session_can_never_reach_a_checkpoint() {
    let dir = tmp_dir("refuse");
    let frames = 3;
    let scene = &make_scenes(1, frames, 270)[0];
    let imgs: Vec<TensorF> =
        (0..frames).map(|i| scene.normalized_image(i)).collect();
    // unguarded server: a NaN pose sails into the session state
    let mut server =
        StreamServer::on_ref_backend(SEED, PipelineOptions::default())
            .unwrap();
    server.open_stream();
    let mut store = SessionStore::open(
        &dir,
        2,
        server.engine().backend().manifest(),
        server.engine().qp().as_ref(),
    )
    .unwrap();
    server.step_stream(0, &imgs[0], &scene.poses[0]).unwrap();
    store.save(server.session(0)).unwrap();
    assert!(store.has_checkpoint(0), "clean state checkpoints fine");
    let mut nan_pose = scene.poses[1];
    nan_pose.0[7] = f64::NAN;
    server.step_stream(0, &imgs[1], &nan_pose).unwrap();
    assert!(!server.session(0).is_finite(), "the poison committed");
    let err = store.save(server.session(0)).unwrap_err();
    assert!(
        err.to_string().contains("non-finite"),
        "store must refuse poisoned state: {err}"
    );
    // the earlier clean checkpoint is untouched by the refused save
    let qp = Arc::clone(server.engine().qp());
    let restored = store.load(0, &qp).unwrap();
    assert_eq!(restored.frames_done(), 1);
    assert!(restored.is_finite());
    // guarded counterpart: the same feed holds the poisoned frame, the
    // session stays finite, and checkpointing keeps working
    let mut guarded = guarded_server(1, GuardOptions::default());
    guarded.step_stream(0, &imgs[0], &scene.poses[0]).unwrap();
    guarded.step_stream(0, &imgs[1], &nan_pose).unwrap();
    assert!(guarded.session(0).is_finite(), "guard kept the poison out");
    let mut store2 = SessionStore::open(
        &dir.join("guarded"),
        2,
        guarded.engine().backend().manifest(),
        guarded.engine().qp().as_ref(),
    )
    .unwrap();
    store2.save(guarded.session(0)).unwrap();
    assert!(store2.has_checkpoint(0));
    let _ = std::fs::remove_dir_all(&dir);
}
