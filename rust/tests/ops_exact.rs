//! Bit-exactness property tests for the PR-3 op-stack fast paths:
//! the SIMD-lane conv interior, the allocation-free `_into`/arena
//! elementwise + sampling + norm ops, the batched conv kernel, and the
//! batched `RefBackend`. Every fast path is pinned against its scalar /
//! allocating / solo specification over randomized shapes, exponents,
//! batch widths and thread counts — mirroring `conv_exact.rs`.

use fadec::config::{A_QMAX, A_QMIN};
use fadec::ops::{
    conv2d_q_packed, conv2d_q_packed_batch, conv2d_q_ref, layer_norm,
    layer_norm_into, resize_bilinear, resize_bilinear_into, upsample_nearest2x_i16,
    upsample_nearest2x_i16_arena, upsample_nearest2x_i16_into, Arena,
    PackedQConv,
};
use fadec::quant::{
    add_q, add_q_arena, add_q_into, concat_q, concat_q_arena, mul_q, mul_q_arena,
    mul_q_into, quantize_f32, quantize_slice, quantize_tensor, requant,
    requant_arena, requant_into, requant_owned, QTensor,
};
use fadec::runtime::{HwBackend, RefBackend};
use fadec::tensor::{Tensor, TensorF, TensorI16, TensorI32, TensorI8};
use fadec::util::Rng;

fn rand_q(rng: &mut Rng, shape: &[usize], exp: i32) -> QTensor {
    let n: usize = shape.iter().product();
    QTensor {
        t: Tensor::from_vec(
            shape,
            (0..n).map(|_| rng.range_i64(-30000, 30000) as i16).collect(),
        ),
        exp,
    }
}

/// An arena pre-seeded with dirty recycled buffers, so stale-content
/// bugs in any `take_*` consumer show up as value differences.
fn dirty_arena(threads: usize) -> Arena {
    let mut a = Arena::with_threads(threads);
    for _ in 0..4 {
        a.recycle_i16(vec![i16::MAX; 97]);
        a.recycle_f32(vec![f32::NAN; 61]);
    }
    a
}

#[test]
fn simd_conv_interior_matches_ref_over_lane_remainder_widths() {
    // widths 1..=20 sweep every n % LANES tail; heights catch row bases
    let mut rng = Rng::new(0x51AD);
    for w in 1..=20usize {
        let (ic, oc, h, k, stride) = (3usize, 4usize, 5usize, 3usize, 1usize);
        let x = QTensor {
            t: Tensor::from_vec(
                &[1, ic, h, w],
                (0..ic * h * w)
                    .map(|_| rng.range_i64(-4000, 4000) as i16)
                    .collect(),
            ),
            exp: 8,
        };
        let wt = TensorI8::from_vec(
            &[oc, ic, k, k],
            (0..oc * ic * k * k)
                .map(|_| rng.range_i64(-127, 127) as i8)
                .collect(),
        );
        let b = TensorI32::from_vec(
            &[oc],
            (0..oc).map(|_| rng.range_i64(-512, 512) as i32).collect(),
        );
        let expect = conv2d_q_ref(&x, &wt, &b, stride, 11, 9, false, 8);
        let pw = PackedQConv::pack_dense(&wt);
        let mut arena = dirty_arena(1);
        let got =
            conv2d_q_packed(&x, &pw, b.data(), stride, 11, 9, false, 8, &mut arena);
        assert_eq!(got.t.data(), expect.t.data(), "w={w}");
    }
}

#[test]
fn elementwise_into_and_arena_variants_match_the_specs() {
    let mut rng = Rng::new(0xE1E);
    let mut arena = dirty_arena(1);
    for trial in 0..100 {
        let c = rng.range_i64(1, 4) as usize;
        let h = rng.range_i64(1, 6) as usize;
        let w = rng.range_i64(1, 9) as usize;
        let shape = [1usize, c, h, w];
        let ea = rng.range_i64(2, 14) as i32;
        let eb = rng.range_i64(2, 14) as i32;
        let eo = rng.range_i64(2, 14) as i32;
        let a = rand_q(&mut rng, &shape, ea);
        let b = rand_q(&mut rng, &shape, eb);
        let n = a.t.len();

        // add
        let spec = add_q(&a, &b, eo);
        let got = add_q_arena(&a, &b, eo, &mut arena);
        assert_eq!(spec.t.data(), got.t.data(), "add trial {trial}");
        assert_eq!(spec.exp, got.exp);
        let mut buf = vec![0i16; n];
        add_q_into(&a, &b, eo, &mut buf);
        assert_eq!(spec.t.data(), &buf[..], "add_into trial {trial}");
        arena.recycle_q(got);

        // mul
        let spec = mul_q(&a, &b, eo);
        let got = mul_q_arena(&a, &b, eo, &mut arena);
        assert_eq!(spec.t.data(), got.t.data(), "mul trial {trial}");
        mul_q_into(&a, &b, eo, &mut buf);
        assert_eq!(spec.t.data(), &buf[..], "mul_into trial {trial}");
        arena.recycle_q(got);

        // requant (incl. the exp == out_exp no-op case every few trials)
        let eo_r = if trial % 5 == 0 { ea } else { eo };
        let spec = requant(&a, eo_r);
        let got = requant_arena(&a, eo_r, &mut arena);
        assert_eq!(spec.t.data(), got.t.data(), "requant trial {trial}");
        requant_into(&a, eo_r, &mut buf);
        assert_eq!(spec.t.data(), &buf[..], "requant_into trial {trial}");
        let owned = requant_owned(a.clone(), eo_r, &mut arena);
        assert_eq!(spec.t.data(), owned.t.data(), "requant_owned trial {trial}");
        assert_eq!(owned.exp, eo_r);
        arena.recycle_q(got);
        arena.recycle_q(owned);

        // concat: new direct-into-output path vs the naive reference
        // (requant every part, then memcpy-concat)
        let parts: Vec<&QTensor> = vec![&a, &b];
        let naive: Vec<QTensor> =
            parts.iter().map(|p| requant(p, eo)).collect();
        let naive_refs: Vec<&TensorI16> = naive.iter().map(|q| &q.t).collect();
        let expect = Tensor::concat_channels(&naive_refs);
        let got = concat_q(&parts, eo);
        assert_eq!(got.t.data(), expect.data(), "concat trial {trial}");
        assert_eq!(got.t.shape(), expect.shape());
        let got_a = concat_q_arena(&parts, eo, &mut arena);
        assert_eq!(got_a.t.data(), expect.data(), "concat_arena trial {trial}");
        arena.recycle_q(got_a);
    }
}

#[test]
fn requant_owned_noop_forwards_the_payload() {
    let mut arena = Arena::new();
    let q = QTensor {
        t: Tensor::from_vec(&[1, 1, 1, 3], vec![1i16, -2, 3]),
        exp: 9,
    };
    let ptr = q.t.data().as_ptr();
    let out = requant_owned(q, 9, &mut arena);
    assert_eq!(out.t.data().as_ptr(), ptr, "no-op requant must not copy");
    assert_eq!(out.t.data(), &[1, -2, 3]);
}

#[test]
fn upsample_and_layer_norm_into_match_their_specs() {
    let mut rng = Rng::new(0x0755);
    for trial in 0..30 {
        let c = rng.range_i64(1, 4) as usize;
        let h = rng.range_i64(1, 7) as usize;
        let w = rng.range_i64(1, 7) as usize;
        // i16 nearest upsample
        let x = TensorI16::from_vec(
            &[1, c, h, w],
            (0..c * h * w)
                .map(|_| rng.range_i64(-30000, 30000) as i16)
                .collect(),
        );
        let spec = upsample_nearest2x_i16(&x);
        let mut buf = vec![0i16; c * 4 * h * w];
        upsample_nearest2x_i16_into(&x, &mut buf);
        assert_eq!(spec.data(), &buf[..], "upsample_into trial {trial}");
        let mut arena = dirty_arena(1);
        let got = upsample_nearest2x_i16_arena(&x, &mut arena);
        assert_eq!(spec.data(), got.data(), "upsample_arena trial {trial}");
        assert_eq!(spec.shape(), got.shape());

        // float bilinear resize (exercise up- and down-scaling)
        let xf = TensorF::from_vec(
            &[1, c, h, w],
            (0..c * h * w).map(|_| rng.normal_f32()).collect(),
        );
        let (oh, ow) = (
            rng.range_i64(1, 10) as usize,
            rng.range_i64(1, 10) as usize,
        );
        let spec = resize_bilinear(&xf, oh, ow);
        let mut fbuf = vec![0f32; c * oh * ow];
        resize_bilinear_into(&xf, oh, ow, &mut fbuf);
        assert_eq!(
            spec.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            fbuf.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "bilinear trial {trial}"
        );

        // layer norm
        let gamma: Vec<f32> = (0..c).map(|_| rng.normal_f32()).collect();
        let beta: Vec<f32> = (0..c).map(|_| rng.normal_f32()).collect();
        let spec = layer_norm(&xf, &gamma, &beta);
        let mut lbuf = vec![0f32; c * h * w];
        layer_norm_into(&xf, &gamma, &beta, &mut lbuf);
        assert_eq!(
            spec.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            lbuf.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "layer_norm trial {trial}"
        );
    }
}

#[test]
fn batched_conv_matches_solo_over_random_shapes_widths_threads() {
    let mut rng = Rng::new(0xBA7C);
    for trial in 0..40 {
        let k = [1usize, 3, 5][rng.below(3) as usize];
        let stride = [1usize, 2][rng.below(2) as usize];
        let ic = rng.range_i64(1, 5) as usize;
        let oc = rng.range_i64(1, 6) as usize;
        let h = rng.range_i64(1, 9) as usize;
        let w = rng.range_i64(1, 9) as usize;
        let width = rng.range_i64(1, 5) as usize;
        let threads = rng.range_i64(1, 4) as usize;
        let s_q = rng.range_i64(1, 127) as i32;
        let r = rng.range_i64(-2, 14) as i32;
        let relu = rng.below(2) == 0;

        let wt = TensorI8::from_vec(
            &[oc, ic, k, k],
            (0..oc * ic * k * k)
                .map(|_| rng.range_i64(-127, 127) as i8)
                .collect(),
        );
        let b: Vec<i32> =
            (0..oc).map(|_| rng.range_i64(-1024, 1024) as i32).collect();
        let pw = PackedQConv::pack_dense(&wt);
        let xs: Vec<QTensor> = (0..width)
            .map(|_| QTensor {
                t: Tensor::from_vec(
                    &[1, ic, h, w],
                    (0..ic * h * w)
                        .map(|_| rng.range_i64(-4000, 4000) as i16)
                        .collect(),
                ),
                exp: 8,
            })
            .collect();
        let solo: Vec<QTensor> = xs
            .iter()
            .map(|x| {
                let mut a = Arena::new();
                conv2d_q_packed(x, &pw, &b, stride, s_q, r, relu, 8, &mut a)
            })
            .collect();
        let refs: Vec<&QTensor> = xs.iter().collect();
        let mut arena = dirty_arena(threads);
        let got = conv2d_q_packed_batch(
            &refs, &pw, &b, stride, s_q, r, relu, 8, &mut arena,
        );
        assert_eq!(got.len(), width);
        for (bi, (g, s)) in got.iter().zip(&solo).enumerate() {
            assert_eq!(
                g.t.data(),
                s.t.data(),
                "trial {trial} batch {bi}: k={k} s={stride} ic={ic} oc={oc} \
                 h={h} w={w} width={width} threads={threads}"
            );
        }
    }
}

#[test]
fn ref_backend_run_batch_matches_run_for_every_segment() {
    // for every manifest segment, random manifest-shaped inputs, batch of
    // three, per-element comparison against solo `run` — covers the whole
    // batched mirror surface (fe_fs / cve / cl_* / cvd_*) in one sweep
    let be = RefBackend::synthetic(11);
    let mut rng = Rng::new(0x5E6);
    let segs = be.manifest().segments.clone();
    for seg in &segs {
        let id = be.resolve(&seg.name).unwrap();
        let batch_inputs: Vec<Vec<QTensor>> = (0..3)
            .map(|_| {
                seg.inputs
                    .iter()
                    .map(|d| QTensor {
                        t: Tensor::from_vec(
                            &d.shape,
                            (0..d.numel())
                                .map(|_| rng.range_i64(-2000, 2000) as i16)
                                .collect(),
                        ),
                        exp: d.exp,
                    })
                    .collect()
            })
            .collect();
        let batch: Vec<Vec<&QTensor>> = batch_inputs
            .iter()
            .map(|ins| ins.iter().collect())
            .collect();
        let batched = be.run_batch(id, &batch).unwrap();
        assert_eq!(batched.len(), 3, "{}", seg.name);
        for (bi, ins) in batch.iter().enumerate() {
            let solo = be.run(id, ins).unwrap();
            assert_eq!(solo.len(), batched[bi].len(), "{}", seg.name);
            for (oi, (s, g)) in solo.iter().zip(&batched[bi]).enumerate() {
                assert_eq!(
                    s.t.data(),
                    g.t.data(),
                    "segment {} batch {bi} output {oi}",
                    seg.name
                );
                assert_eq!(s.exp, g.exp);
            }
        }
    }
}

#[test]
fn quantize_never_launders_nonfinite_floats_into_i16() {
    // PR 10 pin: the quantizer's saturating casts are the last line of
    // defense between a poisoned float and a "valid" i16 activation.
    // The spec: NaN collapses to 0, +/-inf saturate to the activation
    // range bounds, and the slice/tensor fast paths agree with the
    // scalar spec element-for-element — no silent poison either way.
    for exp in [-8, -3, 0, 3, 8] {
        assert_eq!(quantize_f32(f32::NAN, exp), 0, "NaN -> 0 at exp {exp}");
        assert_eq!(
            quantize_f32(f32::INFINITY, exp),
            A_QMAX as i16,
            "+inf saturates at exp {exp}"
        );
        assert_eq!(
            quantize_f32(f32::NEG_INFINITY, exp),
            A_QMIN as i16,
            "-inf saturates at exp {exp}"
        );
        // magnitudes far beyond the representable range saturate too
        assert_eq!(quantize_f32(1.0e30, exp), A_QMAX as i16);
        assert_eq!(quantize_f32(-1.0e30, exp), A_QMIN as i16);
    }
    let mut rng = Rng::new(33);
    let mut vals: Vec<f32> =
        (0..512).map(|_| rng.range_f32(-1.0e6, 1.0e6)).collect();
    vals[7] = f32::NAN;
    vals[63] = f32::INFINITY;
    vals[128] = f32::NEG_INFINITY;
    vals[200] = -f32::NAN;
    vals[311] = f32::MAX;
    vals[479] = f32::MIN;
    for exp in [-8, 0, 8] {
        let mut out = vec![0i16; vals.len()];
        quantize_slice(&vals, exp, &mut out);
        for (i, (&v, &q)) in vals.iter().zip(&out).enumerate() {
            assert_eq!(q, quantize_f32(v, exp), "slice elt {i} at exp {exp}");
            assert!(
                (A_QMIN..=A_QMAX).contains(&(q as i32)),
                "elt {i} escaped the activation range"
            );
        }
        let t = quantize_tensor(&TensorF::from_vec(&[8, 64], vals.clone()), exp);
        assert_eq!(t.t.data(), &out[..], "tensor path at exp {exp}");
        assert_eq!(t.exp, exp);
    }
}
