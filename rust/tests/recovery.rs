//! Fault-tolerance tests (PR 7): seeded chaos schedules — submit
//! faults, wait faults, latency spikes, and a shard dying mid-serving —
//! must leave every depth map bit-identical to a fault-free run, with
//! the recovery machinery's work visible in `RecoveryStats`; and a
//! server killed and rebuilt purely from its session checkpoints must
//! continue each stream exactly where it left off. Together these pin
//! the PR-7 tentpole: durability and recovery are latency features,
//! never semantic ones.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use fadec::coordinator::{
    Coordinator, PipelineOptions, Placement, RetryPolicy, SessionStore,
    ShardRouter, ShardRouterOptions, StreamServer,
};
use fadec::data::dataset::Scene;
use fadec::poses::Mat4;
use fadec::runtime::{ChaosBackend, ChaosOptions, HwBackend, RefBackend};
use fadec::tensor::TensorF;

const SEED: u64 = 7;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("fadec_recovery_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn make_scenes(n_streams: usize, frames: usize, base_seed: u64) -> Vec<Scene> {
    (0..n_streams)
        .map(|s| {
            Scene::synthetic(&format!("rc-{s}"), frames, base_seed + s as u64)
        })
        .collect()
}

/// Fault-free single-stream reference on a clean backend.
fn solo_run(scene: &Scene, n: usize) -> Vec<TensorF> {
    let mut coord =
        Coordinator::on_ref_backend(SEED, PipelineOptions::default()).unwrap();
    (0..n)
        .map(|i| {
            let img = scene.normalized_image(i);
            coord.step(&img, &scene.poses[i]).unwrap().depth
        })
        .collect()
}

/// A fast-backoff retry policy (tests should not sleep for real).
fn fast_retry(attempts: usize) -> RetryPolicy {
    RetryPolicy {
        backoff: Duration::from_micros(50),
        ..RetryPolicy::with_attempts(attempts)
    }
}

/// Serve `frames` lockstep pipelined rounds of every stream on a
/// `StreamServer` over the given backend, returning depths per stream.
fn serve_pipelined(
    backend: Arc<dyn HwBackend>,
    qp: Arc<fadec::model::weights::QuantParams>,
    opts: PipelineOptions,
    scenes: &[Scene],
    frames: usize,
) -> (Vec<Vec<TensorF>>, fadec::metrics::RecoveryStats) {
    let mut server = StreamServer::new(backend, qp, opts).unwrap();
    let streams: Vec<usize> =
        scenes.iter().map(|_| server.open_stream()).collect();
    let imgs: Vec<Vec<TensorF>> = (0..frames)
        .map(|i| scenes.iter().map(|sc| sc.normalized_image(i)).collect())
        .collect();
    let rounds: Vec<Vec<(usize, &TensorF, &Mat4)>> = (0..frames)
        .map(|i| {
            streams
                .iter()
                .map(|&s| (s, &imgs[i][s], &scenes[s].poses[i]))
                .collect()
        })
        .collect();
    let results = server.run_pipelined(&rounds, 2).unwrap();
    let mut depths: Vec<Vec<TensorF>> =
        scenes.iter().map(|_| Vec::new()).collect();
    for mut round in results {
        round.sort_by_key(|&(sid, _)| sid);
        for (sid, out) in round {
            depths[sid].push(out.depth);
        }
    }
    let report = server.report();
    let rec = server.recovery_stats();
    if rec.any() {
        assert!(report.contains("recovery:"), "report surfaces recovery");
    }
    (depths, rec)
}

fn assert_depths_eq(got: &[Vec<TensorF>], want: &[Vec<TensorF>], tag: &str) {
    assert_eq!(got.len(), want.len(), "{tag}: stream count");
    for (s, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.len(), w.len(), "{tag}: stream {s} frame count");
        for (i, (a, b)) in g.iter().zip(w).enumerate() {
            assert_eq!(
                a.data(),
                b.data(),
                "{tag}: stream {s} frame {i} diverged"
            );
        }
    }
}

/// One chaos sweep: serve under the given schedule with retry enabled
/// and demand bit-exact outputs vs the same serving on a clean backend.
fn chaos_sweep(
    tag: &str,
    chaos_opts: ChaosOptions,
    retry: RetryPolicy,
) -> (Arc<ChaosBackend>, fadec::metrics::RecoveryStats) {
    let (n_streams, frames) = (2, 3);
    let scenes = make_scenes(n_streams, frames, 80);
    // reference: identical schedule, clean backend, default options
    let clean = RefBackend::synthetic(SEED);
    let clean_qp = Arc::clone(clean.qp());
    let (want, clean_rec) = serve_pipelined(
        Arc::new(clean),
        clean_qp,
        PipelineOptions::default(),
        &scenes,
        frames,
    );
    assert!(!clean_rec.any(), "{tag}: clean run needs no recovery");
    // chaotic run
    let inner = RefBackend::synthetic(SEED);
    let qp = Arc::clone(inner.qp());
    let chaos = Arc::new(ChaosBackend::new(Arc::new(inner), chaos_opts));
    let opts = PipelineOptions { retry, ..Default::default() };
    let (got, rec) = serve_pipelined(
        Arc::clone(&chaos) as Arc<dyn HwBackend>,
        qp,
        opts,
        &scenes,
        frames,
    );
    assert_depths_eq(&got, &want, tag);
    (chaos, rec)
}

#[test]
fn submit_faults_recover_bit_exactly() {
    let (chaos, rec) = chaos_sweep(
        "submit",
        ChaosOptions {
            seed: 3,
            submit_fault_rate: 1.0,
            heal_after: Some(4),
            ..Default::default()
        },
        fast_retry(6),
    );
    assert_eq!(chaos.faults_injected(), 4, "schedule heals after 4");
    assert_eq!(rec.submit_faults, 4);
    assert_eq!(rec.retries, 4, "every fault cost exactly one retry");
    assert_eq!(rec.giveups, 0);
}

#[test]
fn wait_faults_recover_bit_exactly() {
    let (chaos, rec) = chaos_sweep(
        "wait",
        ChaosOptions {
            seed: 5,
            wait_fault_rate: 1.0,
            heal_after: Some(3),
            ..Default::default()
        },
        fast_retry(5),
    );
    assert_eq!(chaos.faults_injected(), 3);
    assert_eq!(rec.wait_faults, 3);
    assert_eq!(rec.retries, 3);
    assert_eq!(rec.giveups, 0);
}

#[test]
fn latency_spikes_delay_but_never_diverge() {
    let (chaos, rec) = chaos_sweep(
        "latency",
        ChaosOptions {
            seed: 9,
            latency_rate: 1.0,
            latency: Duration::from_micros(200),
            ..Default::default()
        },
        fast_retry(2),
    );
    assert!(chaos.latency_spikes_injected() > 0, "spikes fired");
    assert_eq!(chaos.faults_injected(), 0, "latency is not a fault");
    assert_eq!(rec.retries, 0, "nothing to retry");
}

#[test]
fn mixed_chaos_sweep_is_bit_exact() {
    let (chaos, rec) = chaos_sweep(
        "mixed",
        ChaosOptions {
            seed: 17,
            submit_fault_rate: 0.5,
            wait_fault_rate: 0.5,
            latency_rate: 0.25,
            latency: Duration::from_micros(100),
            heal_after: Some(6),
            ..Default::default()
        },
        fast_retry(8),
    );
    // the seeded schedule injects up to 6 faults over dozens of
    // submissions; every one must have been absorbed by a retry
    assert!(chaos.faults_injected() >= 1, "schedule injected something");
    assert_eq!(
        rec.retries,
        chaos.faults_injected(),
        "one retry per injected fault"
    );
    assert_eq!(rec.submit_faults + rec.wait_faults, chaos.faults_injected());
    assert_eq!(rec.giveups, 0);
}

#[test]
fn shard_death_mid_window_fails_over_bit_exactly() {
    let dir = tmp_dir("failover");
    let (n_streams, frames) = (4, 6);
    let scenes = make_scenes(n_streams, frames, 60);
    let solo: Vec<Vec<TensorF>> =
        scenes.iter().map(|sc| solo_run(sc, frames)).collect();

    // shard 0 is killable (chaos-wrapped), shard 1 is clean
    let inner0 = RefBackend::synthetic(SEED);
    let qp0 = Arc::clone(inner0.qp());
    let chaos =
        Arc::new(ChaosBackend::new(Arc::new(inner0), ChaosOptions::default()));
    let be1 = RefBackend::synthetic(SEED);
    let qp1 = Arc::clone(be1.qp());
    let opts =
        PipelineOptions { retry: fast_retry(3), ..Default::default() };
    let mut router = ShardRouter::new(
        vec![
            (Arc::clone(&chaos) as Arc<dyn HwBackend>, qp0),
            (Arc::new(be1) as Arc<dyn HwBackend>, qp1),
        ],
        opts,
        ShardRouterOptions {
            placement: Placement::RoundRobin,
            auto_rebalance: false,
            imbalance_threshold: 1.5,
        },
    )
    .unwrap();
    let store = SessionStore::open(
        &dir,
        8,
        chaos.manifest(),
        router.engine(0).qp().as_ref(),
    )
    .unwrap();
    router.attach_session_store(store);

    let streams: Vec<usize> =
        (0..n_streams).map(|_| router.open_stream()).collect();
    let on_dead: Vec<usize> = streams
        .iter()
        .copied()
        .filter(|&s| router.shard_of(s) == Some(0))
        .collect();
    assert!(!on_dead.is_empty(), "round-robin placed streams on shard 0");

    let imgs: Vec<Vec<TensorF>> = (0..frames)
        .map(|i| scenes.iter().map(|sc| sc.normalized_image(i)).collect())
        .collect();
    let rounds = |lo: usize, hi: usize| -> Vec<Vec<(usize, &TensorF, &Mat4)>> {
        (lo..hi)
            .map(|i| {
                streams
                    .iter()
                    .map(|&s| (s, &imgs[i][s], &scenes[s].poses[i]))
                    .collect()
            })
            .collect()
    };
    let mut got: Vec<Vec<TensorF>> = (0..n_streams).map(|_| Vec::new()).collect();
    let take = |results: Vec<Vec<(usize, fadec::coordinator::FrameOutput)>>,
                    got: &mut Vec<Vec<TensorF>>| {
        for round in results {
            for (sid, out) in round {
                got[sid].push(out.depth);
            }
        }
    };

    // window 1 (frames 0..2): both shards healthy
    take(router.run_rounds(&rounds(0, 2), 2).unwrap(), &mut got);
    // shard 0 dies; window 2 (frames 2..4) begins unaware — its retries
    // exhaust, failover ships the victims through checkpoints to shard
    // 1 and replays the unfinished rounds there
    chaos.set_dead(true);
    take(router.run_rounds(&rounds(2, 4), 2).unwrap(), &mut got);
    for &s in &on_dead {
        assert_eq!(router.shard_of(s), Some(1), "victim {s} failed over");
        assert_eq!(router.session(s).unwrap().migrations(), 1);
    }
    // window 3 (frames 4..6): serving continues on the survivor alone
    take(router.run_rounds(&rounds(4, 6), 2).unwrap(), &mut got);

    assert_depths_eq(&got, &solo, "failover");
    let rec = router.recovery_stats();
    assert_eq!(rec.shard_failovers, 1, "one shard died once");
    assert_eq!(
        rec.checkpoint_migrations,
        on_dead.len(),
        "every victim shipped through its checkpoint"
    );
    assert!(rec.retries >= 1, "the dead shard was retried before failover");
    assert!(rec.giveups >= 1, "persistent death exhausted a retry budget");
    assert!(rec.checkpoint_bytes > 0);
    assert!(router.report().contains("recovery:"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn kill_and_restart_rebuilds_purely_from_checkpoints() {
    let dir = tmp_dir("restart");
    let (n_streams, frames, cut) = (2, 6, 3);
    let scenes = make_scenes(n_streams, frames, 90);
    let solo: Vec<Vec<TensorF>> =
        scenes.iter().map(|sc| solo_run(sc, frames)).collect();
    let opts = PipelineOptions::default();

    // serve the first half, checkpoint every stream, then "crash"
    // (drop the server — nothing survives but the checkpoint files)
    {
        let mut server = StreamServer::on_ref_backend(SEED, opts).unwrap();
        let mut store = SessionStore::open(
            &dir,
            n_streams,
            server.engine().backend().manifest(),
            server.engine().qp().as_ref(),
        )
        .unwrap();
        for _ in 0..n_streams {
            server.open_stream();
        }
        for i in 0..cut {
            for s in 0..n_streams {
                let img = scenes[s].normalized_image(i);
                let out =
                    server.step_stream(s, &img, &scenes[s].poses[i]).unwrap();
                assert_eq!(out.depth.data(), solo[s][i].data());
            }
        }
        for s in 0..n_streams {
            store.save(server.session(s)).unwrap();
        }
        assert!(store.stats().checkpoint_bytes > 0);
    }

    // restart: a brand-new server adopts every on-disk session and
    // continues each stream bit-exactly from the checkpointed frame
    let mut server = StreamServer::on_ref_backend(SEED, opts).unwrap();
    let mut store = SessionStore::open(
        &dir,
        n_streams,
        server.engine().backend().manifest(),
        server.engine().qp().as_ref(),
    )
    .unwrap();
    let ids = store.list_checkpoints().unwrap();
    assert_eq!(ids, (0..n_streams).collect::<Vec<_>>());
    for id in ids {
        let session = store.load(id, server.engine().qp().as_ref()).unwrap();
        assert_eq!(server.open_stream_restored(session).unwrap(), id);
    }
    assert_eq!(store.stats().restores, n_streams);
    for i in cut..frames {
        for s in 0..n_streams {
            let img = scenes[s].normalized_image(i);
            let out =
                server.step_stream(s, &img, &scenes[s].poses[i]).unwrap();
            assert_eq!(
                out.depth.data(),
                solo[s][i].data(),
                "stream {s} frame {i} after restart"
            );
        }
    }
    // adopting out of order is refused (ids are dense slots)
    let mut other = StreamServer::on_ref_backend(SEED, opts).unwrap();
    let session = store.load(1, other.engine().qp().as_ref()).unwrap();
    let err = other.open_stream_restored(session).unwrap_err();
    assert!(format!("{err:#}").contains("ascending id order"), "{err:#}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn lru_paged_serving_matches_continuous() {
    // two streams served through a capacity-1 store: every round trip
    // suspends one stream to disk and restores the other, and the
    // depths must match streams that never left memory
    let dir = tmp_dir("paged");
    let (n_streams, frames) = (2, 3);
    let scenes = make_scenes(n_streams, frames, 70);
    let solo: Vec<Vec<TensorF>> =
        scenes.iter().map(|sc| solo_run(sc, frames)).collect();
    let coord =
        Coordinator::on_ref_backend(SEED, PipelineOptions::default()).unwrap();
    let mut store = SessionStore::open(
        &dir,
        1,
        coord.engine().backend().manifest(),
        coord.engine().qp().as_ref(),
    )
    .unwrap();
    for (s, _) in scenes.iter().enumerate() {
        store.check_in(coord.engine().new_session(s)).unwrap();
    }
    let qp = Arc::clone(coord.engine().qp());
    for i in 0..frames {
        for (s, scene) in scenes.iter().enumerate() {
            let mut session = store.check_out(s, &qp).unwrap();
            let img = scene.normalized_image(i);
            let out = coord
                .engine()
                .step_session(&mut session, &img, &scene.poses[i])
                .unwrap();
            assert_eq!(
                out.depth.data(),
                solo[s][i].data(),
                "stream {s} frame {i} under paging"
            );
            store.check_in(session).unwrap();
        }
    }
    let st = store.stats();
    assert!(st.evictions >= 5, "capacity 1 pages constantly");
    assert_eq!(st.evictions, st.restores + 1, "all but the last came back");
    let _ = std::fs::remove_dir_all(&dir);
}
