//! Continuous-scheduling tests (PR 8): the overload-safe serving
//! schedule must stay bit-exact per stream under every admission
//! policy, late arrival, shed, backpressure gate and injected chaos —
//! and its scheduling decisions (formed on the virtual tick clock) must
//! be *exactly* deterministic: identical workloads produce identical
//! `SchedulerStats`, fault or no fault. Together these pin the PR-8
//! tentpole: overload handling is a latency/placement feature, never a
//! semantic one.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use fadec::coordinator::{
    AdmissionPolicy, ContinuousStream, Coordinator, PipelineOptions,
    Placement, RetryPolicy, SchedulerOptions, SessionStore, ShardRouter,
    ShardRouterOptions, StreamDisposition, StreamServer,
};
use fadec::data::dataset::Scene;
use fadec::metrics::SchedulerStats;
use fadec::runtime::{ChaosBackend, ChaosOptions, HwBackend, RefBackend};
use fadec::tensor::TensorF;

const SEED: u64 = 7;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("fadec_sched_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn make_scenes(n_streams: usize, frames: usize, base_seed: u64) -> Vec<Scene> {
    (0..n_streams)
        .map(|s| {
            Scene::synthetic(&format!("sc-{s}"), frames, base_seed + s as u64)
        })
        .collect()
}

/// Fault-free single-stream reference on a clean backend.
fn solo_run(scene: &Scene, n: usize) -> Vec<TensorF> {
    let mut coord =
        Coordinator::on_ref_backend(SEED, PipelineOptions::default()).unwrap();
    (0..n)
        .map(|i| {
            let img = scene.normalized_image(i);
            coord.step(&img, &scene.poses[i]).unwrap().depth
        })
        .collect()
}

/// Pre-render every frame of every scene (the continuous set borrows
/// these).
fn render(scenes: &[Scene], frames: usize) -> Vec<Vec<TensorF>> {
    scenes
        .iter()
        .map(|sc| (0..frames).map(|i| sc.normalized_image(i)).collect())
        .collect()
}

/// One weight-1, tick-0 continuous stream per scene over the rendered
/// frames.
fn continuous_set<'f>(
    imgs: &'f [Vec<TensorF>],
    scenes: &[Scene],
) -> Vec<ContinuousStream<'f>> {
    imgs.iter()
        .zip(scenes)
        .enumerate()
        .map(|(sid, (fr, sc))| {
            ContinuousStream::new(
                sid,
                fr.iter().zip(&sc.poses).map(|(im, p)| (im, *p)).collect(),
            )
        })
        .collect()
}

fn assert_prefix_exact(
    got: &[fadec::coordinator::FrameOutput],
    solo: &[TensorF],
    tag: &str,
) {
    for (i, out) in got.iter().enumerate() {
        assert_eq!(
            out.depth.data(),
            solo[i].data(),
            "{tag}: frame {i} diverged from solo"
        );
    }
}

fn fast_retry(attempts: usize) -> RetryPolicy {
    RetryPolicy {
        backoff: Duration::from_micros(50),
        ..RetryPolicy::with_attempts(attempts)
    }
}

#[test]
fn late_joiner_is_bit_exact_vs_solo() {
    let (n, frames) = (3, 4);
    let scenes = make_scenes(n, frames, 110);
    let solo: Vec<Vec<TensorF>> =
        scenes.iter().map(|sc| solo_run(sc, frames)).collect();
    let mut server =
        StreamServer::on_ref_backend(SEED, PipelineOptions::default())
            .unwrap();
    for _ in 0..n {
        server.open_stream();
    }
    let imgs = render(&scenes, frames);
    let streams: Vec<ContinuousStream> = continuous_set(&imgs, &scenes)
        .into_iter()
        .map(|c| if c.sid == 2 { c.arriving(3) } else { c })
        .collect();
    let out = server
        .run_continuous(&streams, &SchedulerOptions::default())
        .unwrap();
    for (s, d) in out.dispositions.iter().enumerate() {
        assert_eq!(*d, StreamDisposition::Completed, "stream {s}");
        assert_eq!(out.outputs[s].len(), frames);
        assert_prefix_exact(&out.outputs[s], &solo[s], "late-joiner");
    }
    assert_eq!(out.stats.admitted, n);
    assert_eq!(out.stats.frames, n * frames);
    // the joiner's arrival gate forced narrow rounds early on
    assert!(out.stats.fill_ratio() < 1.0);
    assert!(server.report().contains("scheduler:"), "report surfaces it");
}

#[test]
fn admission_rejects_deterministically_at_capacity() {
    let (n, frames) = (4, 3);
    let scenes = make_scenes(n, frames, 120);
    let solo: Vec<Vec<TensorF>> =
        scenes.iter().map(|sc| solo_run(sc, frames)).collect();
    let mut server =
        StreamServer::on_ref_backend(SEED, PipelineOptions::default())
            .unwrap();
    for _ in 0..n {
        server.open_stream();
    }
    let imgs = render(&scenes, frames);
    let streams = continuous_set(&imgs, &scenes);
    let opts = SchedulerOptions {
        capacity: 2,
        admission: AdmissionPolicy::Reject,
        ..SchedulerOptions::default()
    };
    let out = server.run_continuous(&streams, &opts).unwrap();
    assert_eq!(
        out.dispositions,
        vec![
            StreamDisposition::Completed,
            StreamDisposition::Completed,
            StreamDisposition::Rejected,
            StreamDisposition::Rejected,
        ],
        "arrival order decides who gets the two slots"
    );
    for s in 0..2 {
        assert_prefix_exact(&out.outputs[s], &solo[s], "admitted");
    }
    assert!(out.outputs[2].is_empty() && out.outputs[3].is_empty());
    assert_eq!(out.stats.admitted, 2);
    assert_eq!(out.stats.rejected, 2);
}

#[test]
fn overload_queue_backfills_and_stays_bit_exact() {
    // 2x-capacity overload under the queue policy: nobody is lost,
    // everyone is served bit-exactly once a slot frees
    let (n, frames) = (4, 3);
    let scenes = make_scenes(n, frames, 130);
    let solo: Vec<Vec<TensorF>> =
        scenes.iter().map(|sc| solo_run(sc, frames)).collect();
    let mut server =
        StreamServer::on_ref_backend(SEED, PipelineOptions::default())
            .unwrap();
    for _ in 0..n {
        server.open_stream();
    }
    let imgs = render(&scenes, frames);
    let streams = continuous_set(&imgs, &scenes);
    let opts = SchedulerOptions {
        capacity: 2,
        admission: AdmissionPolicy::Queue { deadline_ticks: 0 },
        ..SchedulerOptions::default()
    };
    let out = server.run_continuous(&streams, &opts).unwrap();
    for (s, d) in out.dispositions.iter().enumerate() {
        assert_eq!(*d, StreamDisposition::Completed, "stream {s}");
        assert_prefix_exact(&out.outputs[s], &solo[s], "queued");
    }
    assert_eq!(out.stats.queued, 2, "the overload half waited");
    assert_eq!(out.stats.admitted, 4, "but everyone was admitted");
    assert_eq!(out.stats.max_inflight, 1, "budget 1 is lockstep-degenerate");
}

#[test]
fn queue_backfill_is_earliest_deadline_first_and_bit_exact() {
    // PR 10 EDF pin, hand-traced. Capacity 1: stream 0 holds the slot
    // for ticks 0-2 while streams 1 (unbounded wait) and 2 (per-stream
    // queue deadline of 4 ticks, expiring at tick 4) queue in id order
    // at tick 0. When the slot frees at tick 3, EDF backfills stream 2
    // first — FIFO would have picked stream 1 and let stream 2 expire
    // at tick 5. All three must complete, bit-identically to solo.
    let (n, frames) = (3, 3);
    let scenes = make_scenes(n, frames, 135);
    let solo: Vec<Vec<TensorF>> =
        scenes.iter().map(|sc| solo_run(sc, frames)).collect();
    let mut server =
        StreamServer::on_ref_backend(SEED, PipelineOptions::default())
            .unwrap();
    for _ in 0..n {
        server.open_stream();
    }
    let imgs = render(&scenes, frames);
    let streams: Vec<ContinuousStream> = continuous_set(&imgs, &scenes)
        .into_iter()
        .map(|c| if c.sid == 2 { c.queue_deadline(4) } else { c })
        .collect();
    let opts = SchedulerOptions {
        capacity: 1,
        admission: AdmissionPolicy::Queue { deadline_ticks: 0 },
        ..SchedulerOptions::default()
    };
    let out = server.run_continuous(&streams, &opts).unwrap();
    for (s, d) in out.dispositions.iter().enumerate() {
        assert_eq!(
            *d,
            StreamDisposition::Completed,
            "stream {s}: only EDF backfill serves the tight deadline"
        );
        assert_eq!(out.outputs[s].len(), frames);
        assert_prefix_exact(&out.outputs[s], &solo[s], "edf");
    }
    assert_eq!(out.stats.queued, 2);
    assert_eq!(out.stats.admitted, 3);
    assert_eq!(out.stats.rejected, 0, "nobody expired under EDF");
}

#[test]
fn shed_streams_checkpoint_and_resume_bit_exactly() {
    // three equal always-ready streams fighting for a width-1 round
    // with a 1-tick deadline and zero tolerance: the scheduler sheds
    // them deterministically (traceable by hand), each leaves a
    // resumable checkpoint, and both the served prefix and the resumed
    // suffix are bit-identical to solo serving
    let dir = tmp_dir("shed");
    let (n, frames) = (3, 6);
    let scenes = make_scenes(n, frames, 140);
    let solo: Vec<Vec<TensorF>> =
        scenes.iter().map(|sc| solo_run(sc, frames)).collect();
    let mut server =
        StreamServer::on_ref_backend(SEED, PipelineOptions::default())
            .unwrap();
    for _ in 0..n {
        server.open_stream();
    }
    let store = SessionStore::open(
        &dir,
        n,
        server.engine().backend().manifest(),
        server.engine().qp().as_ref(),
    )
    .unwrap();
    server.attach_session_store(store);
    let imgs = render(&scenes, frames);
    let streams = continuous_set(&imgs, &scenes);
    let opts = SchedulerOptions {
        capacity: n,
        round_width: 1,
        frame_deadline_ticks: 1,
        miss_tolerance: 0,
        degrade_first: false,
        ..SchedulerOptions::default()
    };
    let out = server.run_continuous(&streams, &opts).unwrap();
    // hand trace: 0 and 1 are served twice before their 2-tick lateness
    // sheds them; 2 is served once at lateness 2 and sheds immediately
    assert_eq!(
        out.dispositions,
        vec![
            StreamDisposition::Shed { served: 2 },
            StreamDisposition::Shed { served: 2 },
            StreamDisposition::Shed { served: 1 },
        ]
    );
    assert_eq!(out.stats.shed, 3);
    assert_eq!(out.stats.deadline_misses, 3);
    assert_eq!(out.stats.miss_by_lateness, [3, 0, 0, 0, 0]);
    let qp = Arc::clone(server.engine().qp());
    for s in 0..n {
        let served = match out.dispositions[s] {
            StreamDisposition::Shed { served } => served,
            d => panic!("stream {s}: {d:?}"),
        };
        assert_prefix_exact(&out.outputs[s][..], &solo[s], "shed prefix");
        assert_eq!(out.outputs[s].len(), served);
        // the shed checkpoint resumes exactly where service stopped
        let store = server.session_store_mut().unwrap();
        assert!(store.has_checkpoint(s), "shed stream {s} checkpointed");
        let mut resumed = store.load(s, &qp).unwrap();
        for f in served..frames {
            let got = server
                .engine()
                .step_session(
                    &mut resumed,
                    &imgs[s][f],
                    &scenes[s].poses[f],
                )
                .unwrap();
            assert_eq!(
                got.depth.data(),
                solo[s][f].data(),
                "stream {s} frame {f} after resume"
            );
        }
    }
    assert!(server.report().contains("scheduler:"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn evict_to_checkpoint_pages_through_background_writer() {
    // capacity-1 active set with three arrivals: admission evicts the
    // running stream to the store (which itself pages through the
    // PR-8 background writer thread), then resumes everyone FIFO —
    // all served completely and bit-exactly
    let dir = tmp_dir("evict");
    let (n, frames) = (3, 3);
    let scenes = make_scenes(n, frames, 150);
    let solo: Vec<Vec<TensorF>> =
        scenes.iter().map(|sc| solo_run(sc, frames)).collect();
    let mut server =
        StreamServer::on_ref_backend(SEED, PipelineOptions::default())
            .unwrap();
    for _ in 0..n {
        server.open_stream();
    }
    let mut store = SessionStore::open(
        &dir,
        1, // store residency 1: scheduler evictions page via the writer
        server.engine().backend().manifest(),
        server.engine().qp().as_ref(),
    )
    .unwrap();
    store.set_background(true).unwrap();
    server.attach_session_store(store);
    let imgs = render(&scenes, frames);
    let streams = continuous_set(&imgs, &scenes);
    let opts = SchedulerOptions {
        capacity: 1,
        admission: AdmissionPolicy::EvictToCheckpoint,
        ..SchedulerOptions::default()
    };
    let out = server.run_continuous(&streams, &opts).unwrap();
    for (s, d) in out.dispositions.iter().enumerate() {
        assert_eq!(*d, StreamDisposition::Completed, "stream {s}");
        assert_prefix_exact(&out.outputs[s], &solo[s], "evict/resume");
    }
    assert_eq!(out.stats.evicted, 2, "streams 0 and 1 made room for 2");
    assert_eq!(out.stats.resumed, 2, "and both came back");
    server.session_store_mut().unwrap().barrier().unwrap();
    let rec = server.recovery_stats();
    assert!(
        rec.background_flushes >= 1,
        "store paging went through the writer thread: {rec:?}"
    );
    assert!(rec.background_flush_seconds > 0.0);
    assert!(server.report().contains("background"), "report surfaces it");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn inflight_budget_bounds_backpressure() {
    let (n, frames) = (4, 4);
    let scenes = make_scenes(n, frames, 160);
    let solo: Vec<Vec<TensorF>> =
        scenes.iter().map(|sc| solo_run(sc, frames)).collect();
    let run = |budget: usize, payload_cap: u64| {
        let mut server =
            StreamServer::on_ref_backend(SEED, PipelineOptions::default())
                .unwrap();
        for _ in 0..n {
            server.open_stream();
        }
        let imgs = render(&scenes, frames);
        let streams = continuous_set(&imgs, &scenes);
        let opts = SchedulerOptions {
            capacity: n,
            round_width: 1,
            inflight_budget: budget,
            max_inflight_payload_bytes: payload_cap,
            ..SchedulerOptions::default()
        };
        let out = server.run_continuous(&streams, &opts).unwrap();
        for (s, d) in out.dispositions.iter().enumerate() {
            assert_eq!(*d, StreamDisposition::Completed, "stream {s}");
            assert_prefix_exact(&out.outputs[s], &solo[s], "pipelined");
        }
        out.stats
    };
    // budget 2: exactly two rounds ever in flight, and the gate closed
    // (with work ready) at least once
    let st = run(2, 0);
    assert_eq!(st.max_inflight, 2, "reaches but never exceeds the budget");
    assert!(st.backpressure_stalls > 0, "the closed gate drained: {st:?}");
    // a 1-byte payload bound turns budget 2 into serialized rounds:
    // the deterministic payload gate closes after every begin
    let st = run(2, 1);
    assert_eq!(st.max_inflight, 1, "payload gate forbids a second round");
    assert!(st.backpressure_stalls > 0);
}

/// Continuous overload (2x capacity, queue policy) on the given
/// backend; returns per-stream depths plus the run's scheduler stats
/// and the server's recovery accounting.
fn overload_run(
    backend: Arc<dyn HwBackend>,
    qp: Arc<fadec::model::weights::QuantParams>,
    opts: PipelineOptions,
    scenes: &[Scene],
    frames: usize,
) -> (Vec<Vec<TensorF>>, SchedulerStats, fadec::metrics::RecoveryStats) {
    let mut server = StreamServer::new(backend, qp, opts).unwrap();
    for _ in scenes {
        server.open_stream();
    }
    let imgs = render(scenes, frames);
    let streams = continuous_set(&imgs, scenes);
    let sopts = SchedulerOptions {
        capacity: scenes.len() / 2,
        admission: AdmissionPolicy::Queue { deadline_ticks: 0 },
        ..SchedulerOptions::default()
    };
    let out = server.run_continuous(&streams, &sopts).unwrap();
    let depths = out
        .outputs
        .iter()
        .map(|outs| outs.iter().map(|o| o.depth.clone()).collect())
        .collect();
    (depths, out.stats, server.recovery_stats())
}

#[test]
fn chaos_overload_sweep_is_bit_exact_and_deterministic() {
    // the PR-8 acceptance pin: 2x-capacity overload on a faulting
    // backend must (a) keep every admitted stream bit-identical to
    // solo, and (b) make *identical* scheduling decisions to the same
    // overload on a clean backend — virtual-tick scheduling cannot see
    // wall-clock chaos
    let (n, frames) = (4, 3);
    let scenes = make_scenes(n, frames, 170);
    let solo: Vec<Vec<TensorF>> =
        scenes.iter().map(|sc| solo_run(sc, frames)).collect();

    let clean = RefBackend::synthetic(SEED);
    let clean_qp = Arc::clone(clean.qp());
    let (clean_depths, clean_stats, clean_rec) = overload_run(
        Arc::new(clean),
        clean_qp,
        PipelineOptions::default(),
        &scenes,
        frames,
    );
    assert!(!clean_rec.any(), "clean run needs no recovery");

    let inner = RefBackend::synthetic(SEED);
    let qp = Arc::clone(inner.qp());
    let chaos = Arc::new(ChaosBackend::new(
        Arc::new(inner),
        ChaosOptions {
            seed: 3,
            submit_fault_rate: 1.0,
            heal_after: Some(4),
            ..Default::default()
        },
    ));
    let opts =
        PipelineOptions { retry: fast_retry(6), ..Default::default() };
    let (chaos_depths, chaos_stats, chaos_rec) = overload_run(
        Arc::clone(&chaos) as Arc<dyn HwBackend>,
        qp,
        opts,
        &scenes,
        frames,
    );

    for s in 0..n {
        assert_eq!(chaos_depths[s].len(), solo[s].len(), "stream {s}");
        for (i, (a, b)) in
            chaos_depths[s].iter().zip(&clean_depths[s]).enumerate()
        {
            assert_eq!(a.data(), b.data(), "stream {s} frame {i} vs clean");
            assert_eq!(
                a.data(),
                solo[s][i].data(),
                "stream {s} frame {i} vs solo"
            );
        }
    }
    // exact determinism: the chaotic run queued, admitted, formed and
    // finished the very same rounds at the very same virtual ticks
    assert_eq!(chaos_stats, clean_stats, "scheduling saw the chaos");
    // and the faults themselves were absorbed at the retry layer, in
    // exactly the scheduled amount
    assert_eq!(chaos.faults_injected(), 4, "schedule heals after 4");
    assert_eq!(chaos_rec.submit_faults, 4);
    assert_eq!(chaos_rec.retries, 4, "one retry per injected fault");
    assert_eq!(chaos_rec.giveups, 0);
}

#[test]
fn sharded_continuous_spreads_and_stays_bit_exact() {
    let (n, frames) = (4, 3);
    let scenes = make_scenes(n, frames, 180);
    let solo: Vec<Vec<TensorF>> =
        scenes.iter().map(|sc| solo_run(sc, frames)).collect();
    let be0 = RefBackend::synthetic(SEED);
    let qp0 = Arc::clone(be0.qp());
    let be1 = RefBackend::synthetic(SEED);
    let qp1 = Arc::clone(be1.qp());
    let mut router = ShardRouter::new(
        vec![
            (Arc::new(be0) as Arc<dyn HwBackend>, qp0),
            (Arc::new(be1) as Arc<dyn HwBackend>, qp1),
        ],
        PipelineOptions::default(),
        ShardRouterOptions::default(),
    )
    .unwrap();
    for _ in 0..n {
        router.open_stream();
    }
    let imgs = render(&scenes, frames);
    let streams = continuous_set(&imgs, &scenes);
    // per-shard capacity 2: only an even spread admits all four
    let opts = SchedulerOptions {
        capacity: 2,
        admission: AdmissionPolicy::Reject,
        ..SchedulerOptions::default()
    };
    let out = router.run_continuous(&streams, &opts).unwrap();
    for (s, d) in out.dispositions.iter().enumerate() {
        assert_eq!(*d, StreamDisposition::Completed, "stream {s}");
        assert_prefix_exact(&out.outputs[s], &solo[s], "sharded");
    }
    assert_eq!(out.stats.admitted, n, "placement spread the set evenly");
    assert_eq!(out.stats.rejected, 0);
    assert_eq!(router.scheduler_stats().admitted, n);
    assert!(router.report().contains("scheduler:"));
}

#[test]
fn shard_death_fails_continuous_set_over_bit_exactly() {
    let dir = tmp_dir("failover");
    let (n, frames) = (4, 3);
    let scenes = make_scenes(n, frames, 190);
    let solo: Vec<Vec<TensorF>> =
        scenes.iter().map(|sc| solo_run(sc, frames)).collect();
    let inner0 = RefBackend::synthetic(SEED);
    let qp0 = Arc::clone(inner0.qp());
    let chaos =
        Arc::new(ChaosBackend::new(Arc::new(inner0), ChaosOptions::default()));
    let be1 = RefBackend::synthetic(SEED);
    let qp1 = Arc::clone(be1.qp());
    let opts =
        PipelineOptions { retry: fast_retry(2), ..Default::default() };
    let mut router = ShardRouter::new(
        vec![
            (Arc::clone(&chaos) as Arc<dyn HwBackend>, qp0),
            (Arc::new(be1) as Arc<dyn HwBackend>, qp1),
        ],
        opts,
        ShardRouterOptions {
            placement: Placement::RoundRobin,
            auto_rebalance: false,
            imbalance_threshold: 1.5,
        },
    )
    .unwrap();
    let store = SessionStore::open(
        &dir,
        8,
        chaos.manifest(),
        router.engine(0).qp().as_ref(),
    )
    .unwrap();
    router.attach_session_store(store);
    for _ in 0..n {
        router.open_stream();
    }
    // shard 0 is dead before the window: its half of the continuous
    // set exhausts retries, fails over through checkpoints to shard 1,
    // and is re-admitted there for its entire (unserved) frame list
    chaos.set_dead(true);
    let imgs = render(&scenes, frames);
    let streams = continuous_set(&imgs, &scenes);
    let sopts = SchedulerOptions {
        capacity: n, // survivor must fit everyone after the failover
        ..SchedulerOptions::default()
    };
    let out = router.run_continuous(&streams, &sopts).unwrap();
    for (s, d) in out.dispositions.iter().enumerate() {
        assert_eq!(*d, StreamDisposition::Completed, "stream {s}");
        assert_eq!(out.outputs[s].len(), frames);
        assert_prefix_exact(&out.outputs[s], &solo[s], "failover");
    }
    let rec = router.recovery_stats();
    assert_eq!(rec.shard_failovers, 1, "one shard died once");
    assert!(rec.giveups >= 1, "death exhausted a retry budget");
    assert!(
        rec.checkpoint_migrations >= 1,
        "victims shipped through checkpoints: {rec:?}"
    );
    for s in 0..n {
        assert_eq!(router.shard_of(s), Some(1), "stream {s} on the survivor");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
