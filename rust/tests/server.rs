//! Server-layer tests on the artifact-free RefBackend: stream isolation
//! (interleaved == sequential, bit-exact), multi-stream serving, and
//! session recycling. These are the tier-1 guarantees behind the
//! "one bitstream, many streams" model.

use std::sync::Arc;

use fadec::config;
use fadec::coordinator::{Coordinator, PipelineOptions, StreamServer};
use fadec::data::dataset::Scene;
use fadec::model::QuantParams;
use fadec::poses::Mat4;
use fadec::runtime::{HwBackend, RefBackend};
use fadec::tensor::TensorF;

fn shared_backend(seed: u64) -> (Arc<RefBackend>, Arc<QuantParams>) {
    let backend = Arc::new(RefBackend::synthetic(seed));
    let qp = Arc::clone(backend.qp());
    (backend, qp)
}

/// Run one scene start-to-finish on a fresh coordinator over `backend`.
fn run_sequential(
    backend: &Arc<RefBackend>,
    qp: &Arc<QuantParams>,
    scene: &Scene,
    n: usize,
) -> Vec<TensorF> {
    let mut coord = Coordinator::with_backend(
        Arc::clone(backend) as Arc<dyn HwBackend>,
        Arc::clone(qp),
        PipelineOptions::default(),
    )
    .unwrap();
    (0..n)
        .map(|i| {
            let img = scene.normalized_image(i);
            coord.step(&img, &scene.poses[i]).unwrap().depth
        })
        .collect()
}

#[test]
fn conv_threads_do_not_change_any_depth_bit() {
    // the conv_threads knob stripes conv output channels over scoped
    // workers; the full pipeline output must be bit-identical to the
    // serial kernel for every thread count
    let scene = Scene::synthetic("threads", 3, 5);
    let run = |threads: usize| -> Vec<TensorF> {
        let mut coord = Coordinator::on_ref_backend(
            31,
            PipelineOptions { conv_threads: threads, ..Default::default() },
        )
        .unwrap();
        (0..3)
            .map(|i| {
                let img = scene.normalized_image(i);
                coord.step(&img, &scene.poses[i]).unwrap().depth
            })
            .collect()
    };
    let base = run(1);
    for threads in [2, 4] {
        let got = run(threads);
        for (f, (a, b)) in base.iter().zip(&got).enumerate() {
            assert_eq!(a.data(), b.data(), "frame {f}, conv_threads={threads}");
        }
    }
}

#[test]
fn server_on_ref_backend_honors_conv_threads() {
    // the StreamServer convenience constructor must serve frames and
    // apply conv_threads through the same HwBackend hint as the
    // coordinator path — bit-identically for any worker count
    let scene = Scene::synthetic("srv", 2, 6);
    let run = |threads: usize| -> Vec<TensorF> {
        let mut server = StreamServer::on_ref_backend(
            17,
            PipelineOptions { conv_threads: threads, ..Default::default() },
        )
        .unwrap();
        let s = server.open_stream();
        (0..2)
            .map(|i| {
                let img = scene.normalized_image(i);
                server.step_stream(s, &img, &scene.poses[i]).unwrap().depth
            })
            .collect()
    };
    let serial = run(1);
    let threaded = run(4);
    for (f, (a, b)) in serial.iter().zip(&threaded).enumerate() {
        assert_eq!(a.data(), b.data(), "frame {f}");
    }
}

#[test]
fn interleaved_streams_are_bit_identical_to_sequential() {
    // Two streams with *different* trajectories share one backend. The
    // server interleaves them frame by frame; every per-stream depth must
    // be bit-identical to running each stream alone — any leaked h / c /
    // depth / keyframe state between sessions breaks this exactly.
    let (backend, qp) = shared_backend(99);
    let scene_a = Scene::synthetic("stream-a", 4, 1);
    let scene_b = Scene::synthetic("stream-b", 4, 2);
    let n = 4;

    let seq_a = run_sequential(&backend, &qp, &scene_a, n);
    let seq_b = run_sequential(&backend, &qp, &scene_b, n);

    let mut server = StreamServer::new(
        Arc::clone(&backend) as Arc<dyn HwBackend>,
        Arc::clone(&qp),
        PipelineOptions::default(),
    )
    .unwrap();
    let a = server.open_stream();
    let b = server.open_stream();
    assert_eq!((a, b), (0, 1));

    let mut inter_a = Vec::new();
    let mut inter_b = Vec::new();
    for i in 0..n {
        let img_a = scene_a.normalized_image(i);
        let img_b = scene_b.normalized_image(i);
        let outs = server
            .run_round(&[
                (a, &img_a, &scene_a.poses[i]),
                (b, &img_b, &scene_b.poses[i]),
            ])
            .unwrap();
        assert_eq!(outs.len(), 2);
        for (sid, out) in outs {
            if sid == a {
                inter_a.push(out.depth);
            } else {
                inter_b.push(out.depth);
            }
        }
    }

    for i in 0..n {
        assert_eq!(
            inter_a[i].data(),
            seq_a[i].data(),
            "stream A frame {i}: interleaving changed the output"
        );
        assert_eq!(
            inter_b[i].data(),
            seq_b[i].data(),
            "stream B frame {i}: interleaving changed the output"
        );
    }
    assert_eq!(server.session(a).frames_done(), n);
    assert_eq!(server.session(b).frames_done(), n);
}

#[test]
fn batched_rounds_are_bit_identical_for_every_width_and_thread_count() {
    // run_round advances the round in lockstep and batches every HW
    // segment through HwBackend::run_batch; sweep batch widths and conv
    // worker counts and pin each stream's depths against solo serving
    let n_frames = 2;
    let scenes: Vec<Scene> = (0..3)
        .map(|s| Scene::synthetic(&format!("bw{s}"), n_frames, 40 + s as u64))
        .collect();
    let (backend, qp) = shared_backend(77);
    let solo: Vec<Vec<TensorF>> = scenes
        .iter()
        .map(|sc| run_sequential(&backend, &qp, sc, n_frames))
        .collect();
    for width in 1..=3usize {
        for threads in [1usize, 3] {
            let mut server = StreamServer::on_ref_backend(
                77,
                PipelineOptions { conv_threads: threads, ..Default::default() },
            )
            .unwrap();
            let streams: Vec<usize> =
                (0..width).map(|_| server.open_stream()).collect();
            for i in 0..n_frames {
                let imgs: Vec<TensorF> = (0..width)
                    .map(|s| scenes[s].normalized_image(i))
                    .collect();
                let inputs: Vec<_> = streams
                    .iter()
                    .map(|&s| (s, &imgs[s], &scenes[s].poses[i]))
                    .collect();
                let outs = server.run_round(&inputs).unwrap();
                assert_eq!(outs.len(), width);
                for (sid, out) in outs {
                    assert_eq!(
                        out.depth.data(),
                        solo[sid][i].data(),
                        "width={width} threads={threads} stream={sid} frame={i}"
                    );
                }
            }
            let bs = server.batch_stats();
            assert_eq!(bs.rounds, n_frames);
            assert_eq!(bs.max_width, width);
        }
    }
}

#[test]
fn pipelined_serving_is_bit_identical_to_sequential_for_any_depth() {
    // run_pipelined keeps up to K rounds in flight through the backend's
    // async submit queue; every frame of every stream must stay
    // bit-identical to serving that stream alone, for K=1 (lockstep
    // degenerate case) and for real pipelining depths. Every frame walks
    // all 19 manifest segments, so this pins the whole segment path.
    let n_frames = 3;
    let n_streams = 3;
    let scenes: Vec<Scene> = (0..n_streams)
        .map(|s| Scene::synthetic(&format!("pl{s}"), n_frames, 60 + s as u64))
        .collect();
    let (backend, qp) = shared_backend(55);
    let solo: Vec<Vec<TensorF>> = scenes
        .iter()
        .map(|sc| run_sequential(&backend, &qp, sc, n_frames))
        .collect();
    // materialize every frame so the rounds can borrow them
    let imgs: Vec<Vec<TensorF>> = (0..n_frames)
        .map(|i| scenes.iter().map(|sc| sc.normalized_image(i)).collect())
        .collect();
    for k in 1..=3usize {
        let mut server =
            StreamServer::on_ref_backend(55, PipelineOptions::default())
                .unwrap();
        let streams: Vec<usize> =
            (0..n_streams).map(|_| server.open_stream()).collect();
        let rounds: Vec<Vec<(usize, &TensorF, &Mat4)>> = (0..n_frames)
            .map(|i| {
                streams
                    .iter()
                    .map(|&s| (s, &imgs[i][s], &scenes[s].poses[i]))
                    .collect()
            })
            .collect();
        let results = server.run_pipelined(&rounds, k).unwrap();
        assert_eq!(results.len(), n_frames);
        for (i, outs) in results.iter().enumerate() {
            assert_eq!(outs.len(), n_streams, "depth={k} round {i}");
            for (sid, out) in outs {
                assert_eq!(
                    out.depth.data(),
                    solo[*sid][i].data(),
                    "depth={k} stream={sid} frame={i}: pipelined serving \
                     diverged from sequential"
                );
            }
        }
        let bs = server.batch_stats();
        assert_eq!(bs.pipelined_rounds, n_frames, "depth={k}");
        assert_eq!(bs.rounds, n_frames, "depth={k}");
        assert_eq!(bs.max_width, n_streams, "depth={k}");
        assert_eq!(bs.max_inflight, k.min(n_frames), "depth={k}");
        assert!(bs.fill_seconds >= 0.0 && bs.drain_seconds >= 0.0);
        for &s in &streams {
            assert_eq!(server.session(s).frames_done(), n_frames);
            assert_eq!(server.stream_throughput(s).frames, n_frames);
        }
    }
}

#[test]
fn pipelined_depth2_reports_nonzero_hw_overlap() {
    // with K=2 the backend worker executes round r+1's FeFs while the
    // serving thread runs round r's software stages: the window's HW
    // timeline must show time hidden behind SW
    let n_frames = 4;
    let n_streams = 4;
    let scenes: Vec<Scene> = (0..n_streams)
        .map(|s| Scene::synthetic(&format!("ov{s}"), n_frames, 80 + s as u64))
        .collect();
    let mut server =
        StreamServer::on_ref_backend(21, PipelineOptions::default()).unwrap();
    let streams: Vec<usize> =
        (0..n_streams).map(|_| server.open_stream()).collect();
    let imgs: Vec<Vec<TensorF>> = (0..n_frames)
        .map(|i| scenes.iter().map(|sc| sc.normalized_image(i)).collect())
        .collect();
    let rounds: Vec<Vec<(usize, &TensorF, &Mat4)>> = (0..n_frames)
        .map(|i| {
            streams
                .iter()
                .map(|&s| (s, &imgs[i][s], &scenes[s].poses[i]))
                .collect()
        })
        .collect();
    server.run_pipelined(&rounds, 2).unwrap();
    let bs = server.batch_stats();
    assert_eq!(bs.max_inflight, 2);
    assert!(
        bs.pipelined_hw_seconds > 0.0 && bs.pipelined_sw_seconds > 0.0,
        "window recorded busy time on both lanes: {bs:?}"
    );
    assert!(
        bs.overlapped_hw_seconds > 0.0,
        "K=2 pipelining hid no HW time behind SW: {bs:?}"
    );
    assert!(bs.overlapped_hw_ratio() > 0.0);
    let report = server.report();
    assert!(report.contains("pipelined rounds:"), "{report}");
}

#[test]
fn round_rotation_is_fair_under_varying_widths() {
    // width changes between rounds (a stream joining/leaving) must not
    // skew whose turn it is to lead a round: each width rotates by its
    // own served-round counter. The old global-counter scheme pinned
    // width-2 rounds to the same leader forever (0%2, 2%2, 4%2, ...).
    let mut server =
        StreamServer::on_ref_backend(9, PipelineOptions::default()).unwrap();
    let s0 = server.open_stream();
    let s1 = server.open_stream();
    let s2 = server.open_stream();
    let scenes: Vec<Scene> = (0..3)
        .map(|s| Scene::synthetic(&format!("rot{s}"), 6, 90 + s as u64))
        .collect();
    let mut next_frame = [0usize; 3];
    let mut serve = |server: &mut StreamServer, sids: &[usize]| -> usize {
        let imgs: Vec<TensorF> = sids
            .iter()
            .map(|&s| scenes[s].normalized_image(next_frame[s]))
            .collect();
        let inputs: Vec<_> = sids
            .iter()
            .zip(&imgs)
            .map(|(&s, img)| (s, img, &scenes[s].poses[next_frame[s]]))
            .collect();
        let outs = server.run_round(&inputs).unwrap();
        for &s in sids {
            next_frame[s] += 1;
        }
        outs[0].0 // the round's leader (first served stream)
    };
    // alternate width-2 and width-3 rounds; each width rotates fairly
    // through its own participants regardless of the other width's turns
    assert_eq!(serve(&mut server, &[s0, s1]), s0);
    assert_eq!(serve(&mut server, &[s0, s1, s2]), s0);
    assert_eq!(serve(&mut server, &[s0, s1]), s1);
    assert_eq!(serve(&mut server, &[s0, s1, s2]), s1);
    assert_eq!(serve(&mut server, &[s0, s1]), s0);
    assert_eq!(serve(&mut server, &[s0, s1, s2]), s2);
}

#[test]
fn four_streams_serve_concurrently_with_throughput_accounting() {
    let (backend, qp) = shared_backend(5);
    let mut server = StreamServer::new(
        Arc::clone(&backend) as Arc<dyn HwBackend>,
        qp,
        PipelineOptions::default(),
    )
    .unwrap();
    let streams: Vec<usize> =
        (0..config::DEFAULT_STREAMS).map(|_| server.open_stream()).collect();
    assert_eq!(server.n_streams(), config::DEFAULT_STREAMS);
    let scenes: Vec<Scene> = streams
        .iter()
        .map(|&s| Scene::synthetic(&format!("s{s}"), 2, 30 + s as u64))
        .collect();

    for i in 0..2 {
        let imgs: Vec<TensorF> =
            scenes.iter().map(|sc| sc.normalized_image(i)).collect();
        let inputs: Vec<_> = streams
            .iter()
            .map(|&s| (s, &imgs[s], &scenes[s].poses[i]))
            .collect();
        let outs = server.run_round(&inputs).unwrap();
        assert_eq!(outs.len(), config::DEFAULT_STREAMS);
        for (_, out) in &outs {
            assert!(out.depth.data().iter().all(|&d| {
                (config::MIN_DEPTH - 1e-3..=config::MAX_DEPTH + 1e-3)
                    .contains(&d)
            }));
        }
    }

    for &s in &streams {
        let t = server.stream_throughput(s);
        assert_eq!(t.frames, 2);
        assert!(t.busy_seconds > 0.0);
        assert!(t.fps() > 0.0);
    }
    let agg = server.aggregate();
    assert_eq!(agg.streams, config::DEFAULT_STREAMS);
    assert_eq!(agg.frames, 2 * config::DEFAULT_STREAMS);
    assert!(agg.busy_fps() > 0.0 && agg.wall_fps() > 0.0);
    let report = server.report();
    assert!(report.contains("aggregate:"), "{report}");
    assert!(report.contains("backend 'ref'"), "{report}");
    // extern crossings happened and the overhead definition held
    let stats = server.take_extern_stats();
    assert!(!stats.records.is_empty());
    assert!(stats.total_overhead() >= 0.0);
}

#[test]
fn stream_reset_recycles_a_slot_without_leaking_state() {
    // Serving video 1 on a slot, resetting it, then serving video 2 must
    // equal serving video 2 on a fresh server (KB + hidden state fully
    // cleared).
    let (backend, qp) = shared_backend(13);
    let video1 = Scene::synthetic("v1", 3, 3);
    let video2 = Scene::synthetic("v2", 3, 4);

    let fresh = run_sequential(&backend, &qp, &video2, 3);

    let mut server = StreamServer::new(
        Arc::clone(&backend) as Arc<dyn HwBackend>,
        Arc::clone(&qp),
        PipelineOptions::default(),
    )
    .unwrap();
    let s = server.open_stream();
    for i in 0..3 {
        let img = video1.normalized_image(i);
        server.step_stream(s, &img, &video1.poses[i]).unwrap();
    }
    assert!(server.session(s).frames_done() == 3);
    assert!(!server.session(s).kb.is_empty(), "video 1 populated the KB");
    server.reset_stream(s);
    assert!(server.session(s).is_cold());
    assert!(server.session(s).kb.is_empty());
    for i in 0..3 {
        let img = video2.normalized_image(i);
        let out = server.step_stream(s, &img, &video2.poses[i]).unwrap();
        assert_eq!(
            out.depth.data(),
            fresh[i].data(),
            "frame {i}: recycled slot diverged from a fresh session"
        );
    }
}

#[test]
fn stepping_an_unknown_stream_errors() {
    let (backend, qp) = shared_backend(1);
    let mut server =
        StreamServer::new(backend as Arc<dyn HwBackend>, qp, PipelineOptions::default())
            .unwrap();
    let scene = Scene::synthetic("x", 1, 1);
    let img = scene.normalized_image(0);
    let err = server.step_stream(7, &img, &scene.poses[0]).err().unwrap();
    assert!(format!("{err}").contains("stream 7"), "{err}");
}
