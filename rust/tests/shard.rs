//! Shard-layer tests on the artifact-free RefBackend: K-shard serving
//! is bit-exact vs a solo coordinator for K ∈ {1, 2, 4}, live migration
//! mid-run changes nothing but placement, a failing shard surfaces its
//! error without wedging the healthy shards, and the rebalancer drains
//! deliberate skew while staying bit-exact. These pin the tentpole
//! guarantee: sharding is a latency optimisation, never a semantic one.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Result;
use fadec::coordinator::{
    Coordinator, Placement, PipelineOptions, ShardRouter, ShardRouterOptions,
};
use fadec::data::dataset::Scene;
use fadec::data::{Manifest, SegmentDesc};
use fadec::poses::Mat4;
use fadec::quant::QTensor;
use fadec::runtime::{HwBackend, RefBackend, SegmentId};
use fadec::tensor::TensorF;

/// One scene served start-to-finish on a fresh single-backend
/// coordinator with the given seed — the bit-exactness reference for
/// every sharded run below (same-seed synthetic backends compute the
/// same function).
fn solo_run(seed: u64, scene: &Scene, n: usize) -> Vec<TensorF> {
    let mut coord =
        Coordinator::on_ref_backend(seed, PipelineOptions::default()).unwrap();
    (0..n)
        .map(|i| {
            let img = scene.normalized_image(i);
            coord.step(&img, &scene.poses[i]).unwrap().depth
        })
        .collect()
}

fn make_scenes(n_streams: usize, frames: usize, base_seed: u64) -> Vec<Scene> {
    (0..n_streams)
        .map(|s| {
            Scene::synthetic(&format!("sh-{s}"), frames, base_seed + s as u64)
        })
        .collect()
}

fn no_rebalance() -> ShardRouterOptions {
    ShardRouterOptions { auto_rebalance: false, ..Default::default() }
}

#[test]
fn sharded_fleets_are_bit_exact_for_k_1_2_4() {
    const SEED: u64 = 7;
    let (n_streams, frames) = (4, 3);
    let scenes = make_scenes(n_streams, frames, 40);
    let solo: Vec<Vec<TensorF>> =
        scenes.iter().map(|sc| solo_run(SEED, sc, frames)).collect();
    let imgs: Vec<Vec<TensorF>> = (0..frames)
        .map(|i| scenes.iter().map(|sc| sc.normalized_image(i)).collect())
        .collect();
    for k in [1usize, 2, 4] {
        let mut router = ShardRouter::on_ref_backends(
            k,
            SEED,
            PipelineOptions::default(),
            no_rebalance(),
        )
        .unwrap();
        let streams: Vec<usize> =
            (0..n_streams).map(|_| router.open_stream()).collect();
        // least-loaded default placement interleaves the streams over
        // every shard — no shard left idle
        let used: Vec<usize> = (0..k)
            .filter(|&sh| streams.iter().any(|&s| router.shard_of(s) == Some(sh)))
            .collect();
        assert_eq!(used.len(), k.min(n_streams), "k={k}: idle shard");
        let rounds: Vec<Vec<(usize, &TensorF, &Mat4)>> = (0..frames)
            .map(|i| {
                streams
                    .iter()
                    .map(|&s| (s, &imgs[i][s], &scenes[s].poses[i]))
                    .collect()
            })
            .collect();
        let results = router.run_rounds(&rounds, 2).unwrap();
        assert_eq!(results.len(), frames);
        for (r, round) in results.iter().enumerate() {
            assert_eq!(round.len(), n_streams, "k={k} round {r}");
            for (sid, out) in round {
                assert_eq!(
                    out.depth.data(),
                    solo[*sid][r].data(),
                    "k={k} stream {sid} frame {r}: sharded != solo"
                );
            }
        }
        assert_eq!(router.migrations(), 0);
    }
}

#[test]
fn mid_run_migration_is_bit_exact_and_counted() {
    const SEED: u64 = 11;
    let (n_streams, frames) = (3, 4);
    let scenes = make_scenes(n_streams, frames, 60);
    let imgs: Vec<Vec<TensorF>> = (0..frames)
        .map(|i| scenes.iter().map(|sc| sc.normalized_image(i)).collect())
        .collect();
    let run = |migrate_at: Option<usize>| -> (Vec<Vec<TensorF>>, usize) {
        let mut router = ShardRouter::on_ref_backends(
            2,
            SEED,
            PipelineOptions::default(),
            no_rebalance(),
        )
        .unwrap();
        let streams: Vec<usize> =
            (0..n_streams).map(|_| router.open_stream()).collect();
        let mut outs: Vec<Vec<TensorF>> = vec![Vec::new(); n_streams];
        for i in 0..frames {
            if migrate_at == Some(i) {
                let from = router.shard_of(streams[0]).unwrap();
                router.migrate_stream(streams[0], 1 - from).unwrap();
                assert_eq!(router.shard_of(streams[0]), Some(1 - from));
                assert_eq!(router.session(streams[0]).unwrap().migrations(), 1);
            }
            let round: Vec<(usize, &TensorF, &Mat4)> = streams
                .iter()
                .map(|&s| (s, &imgs[i][s], &scenes[s].poses[i]))
                .collect();
            for (sid, out) in router.run_round(&round).unwrap() {
                outs[sid].push(out.depth);
            }
        }
        (outs, router.migrations())
    };
    let (stay, m_stay) = run(None);
    let (moved, m_moved) = run(Some(frames / 2));
    assert_eq!(m_stay, 0);
    assert_eq!(m_moved, 1);
    for (s, (a, b)) in stay.iter().zip(&moved).enumerate() {
        assert_eq!(a.len(), frames);
        for (f, (da, db)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(
                da.data(),
                db.data(),
                "stream {s} frame {f}: migration changed a depth bit"
            );
        }
    }
}

/// A backend that delegates everything to an inner `RefBackend` but
/// errors out of the execution paths while `fail` is raised — the
/// injected-fault stand-in for a wedged bitstream.
struct FailingBackend {
    inner: Arc<RefBackend>,
    fail: AtomicBool,
}

impl FailingBackend {
    fn check(&self) -> Result<()> {
        anyhow::ensure!(
            !self.fail.load(Ordering::SeqCst),
            "injected fault: shard hardware unresponsive"
        );
        Ok(())
    }
}

impl HwBackend for FailingBackend {
    fn kind(&self) -> &'static str {
        "failing-ref"
    }
    fn manifest(&self) -> &Manifest {
        self.inner.manifest()
    }
    fn resolve(&self, name: &str) -> Result<SegmentId> {
        self.inner.resolve(name)
    }
    fn segment_desc(&self, id: SegmentId) -> &SegmentDesc {
        self.inner.segment_desc(id)
    }
    fn run(&self, id: SegmentId, inputs: &[&QTensor]) -> Result<Vec<QTensor>> {
        self.check()?;
        self.inner.run(id, inputs)
    }
    fn run_batch(
        &self,
        id: SegmentId,
        batch: &[Vec<&QTensor>],
    ) -> Result<Vec<Vec<QTensor>>> {
        self.check()?;
        self.inner.run_batch(id, batch)
    }
    fn set_conv_threads(&self, threads: usize) {
        self.inner.set_conv_threads(threads)
    }
}

#[test]
fn failing_shard_surfaces_error_without_wedging_the_fleet() {
    const SEED: u64 = 13;
    let frames = 3;
    let scenes = make_scenes(2, frames, 80);
    let healthy = Arc::new(RefBackend::synthetic(SEED));
    let qp = Arc::clone(healthy.qp());
    let flaky_inner = Arc::new(RefBackend::synthetic(SEED));
    let flaky_qp = Arc::clone(flaky_inner.qp());
    let flaky = Arc::new(FailingBackend {
        inner: flaky_inner,
        fail: AtomicBool::new(false),
    });
    let mut router = ShardRouter::new(
        vec![
            (healthy as Arc<dyn HwBackend>, qp),
            (Arc::clone(&flaky) as Arc<dyn HwBackend>, flaky_qp),
        ],
        PipelineOptions::default(),
        ShardRouterOptions {
            placement: Placement::Pinned(0),
            ..no_rebalance()
        },
    )
    .unwrap();
    let s0 = router.open_stream();
    router.set_placement(Placement::Pinned(1));
    let s1 = router.open_stream();
    assert_eq!(router.shard_of(s0), Some(0));
    assert_eq!(router.shard_of(s1), Some(1));

    let imgs: Vec<Vec<TensorF>> = (0..frames)
        .map(|i| scenes.iter().map(|sc| sc.normalized_image(i)).collect())
        .collect();
    let round = |i: usize, only: Option<usize>| {
        [s0, s1]
            .into_iter()
            .filter(|&s| only.is_none() || only == Some(s))
            .map(|s| (s, &imgs[i][s], &scenes[s].poses[i]))
            .collect::<Vec<_>>()
    };

    // frame 0: both shards healthy
    router.run_round(&round(0, None)).unwrap();

    // frame 1: shard 1's hardware dies mid-service
    flaky.fail.store(true, Ordering::SeqCst);
    let err = router.run_round(&round(1, None)).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("shard 1"), "error does not name the shard: {msg}");
    assert!(msg.contains("injected fault"), "root cause lost: {msg}");

    // the failure must not wedge the fleet: every session is checked
    // back in, and the healthy shard kept serving its round
    assert!(router.session(s0).is_some());
    assert!(router.session(s1).is_some());
    assert_eq!(router.session(s0).unwrap().frames_done(), 2);
    assert_eq!(router.session(s1).unwrap().frames_done(), 1);
    router.run_round(&round(2, Some(s0))).unwrap();
    assert_eq!(router.session(s0).unwrap().frames_done(), 3);

    // recovery: migrate the stranded stream off the dead shard and
    // replay its remaining frames bit-exactly (vs an uninterrupted solo
    // run on a same-seed backend)
    router.migrate_stream(s1, 0).unwrap();
    let solo = solo_run(SEED, &scenes[s1], frames);
    for i in 1..frames {
        let outs = router.run_round(&round(i, Some(s1))).unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(
            outs[0].1.depth.data(),
            solo[i].data(),
            "frame {i}: recovery after shard failure diverged"
        );
    }
    assert_eq!(router.session(s1).unwrap().migrations(), 1);
}

#[test]
fn auto_rebalance_drains_skew_and_stays_bit_exact() {
    const SEED: u64 = 17;
    let (n_streams, frames) = (4, 4);
    let scenes = make_scenes(n_streams, frames, 90);
    let solo: Vec<Vec<TensorF>> =
        scenes.iter().map(|sc| solo_run(SEED, sc, frames)).collect();
    let imgs: Vec<Vec<TensorF>> = (0..frames)
        .map(|i| scenes.iter().map(|sc| sc.normalized_image(i)).collect())
        .collect();
    // worst-case placement: every stream pinned onto shard 0, with the
    // default auto-rebalance left on (it runs at each window boundary)
    let mut router = ShardRouter::on_ref_backends(
        2,
        SEED,
        PipelineOptions::default(),
        ShardRouterOptions {
            placement: Placement::Pinned(0),
            ..Default::default()
        },
    )
    .unwrap();
    let streams: Vec<usize> =
        (0..n_streams).map(|_| router.open_stream()).collect();
    assert!(streams.iter().all(|&s| router.shard_of(s) == Some(0)));
    for i in 0..frames {
        let round: Vec<(usize, &TensorF, &Mat4)> = streams
            .iter()
            .map(|&s| (s, &imgs[i][s], &scenes[s].poses[i]))
            .collect();
        for (sid, out) in router.run_round(&round).unwrap() {
            assert_eq!(
                out.depth.data(),
                solo[sid][i].data(),
                "stream {sid} frame {i}: rebalanced serving diverged"
            );
        }
    }
    assert!(router.migrations() >= 1, "skew never drained");
    let on_1 = streams
        .iter()
        .filter(|&&s| router.shard_of(s) == Some(1))
        .count();
    assert!(on_1 >= 1, "no stream ever moved off the hot shard");
}

#[test]
fn placement_policies_spread_as_documented() {
    const SEED: u64 = 19;
    let mut router = ShardRouter::on_ref_backends(
        2,
        SEED,
        PipelineOptions::default(),
        ShardRouterOptions {
            placement: Placement::RoundRobin,
            ..no_rebalance()
        },
    )
    .unwrap();
    let placed: Vec<usize> = (0..4)
        .map(|_| {
            let s = router.open_stream();
            router.shard_of(s).unwrap()
        })
        .collect();
    assert_eq!(placed, vec![0, 1, 0, 1]);
    assert_eq!(router.n_streams(), 4);
    assert_eq!(router.n_shards(), 2);
}
