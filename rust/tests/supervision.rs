//! Process-isolation and supervision tests (PR 9): backends hosted in
//! supervised worker *processes* must serve bit-identically to the
//! in-process fleet; a worker killed with SIGKILL mid-window must fail
//! over through checkpoints with the served suffix bit-exact; a hung
//! worker (stalled serve loop or frozen process) must be detected — by
//! the per-wait deadline or by heartbeat staleness respectively — and
//! restarted under the supervisor's budget with bit-exact
//! continuation; an exhausted restart budget must surface as a typed
//! [`fadec::runtime::BackendDown`] error without wedging the caller;
//! and the length-prefixed frame codec must reject torn and hostile
//! byte streams rather than resynchronize by guessing.
//!
//! Every fault schedule here is deterministic (explicit kill / stall /
//! freeze calls, never timing races on the happy path), so the
//! `SupervisorStats` assertions are exact counts, not lower bounds.

use std::io::Cursor;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use fadec::coordinator::{
    Coordinator, Placement, PipelineOptions, RetryPolicy, SessionStore,
    ShardRouter, ShardRouterOptions, StreamServer,
};
use fadec::data::dataset::Scene;
use fadec::data::tlv::{TlvEntry, TlvFile, TlvPayload};
use fadec::poses::Mat4;
use fadec::runtime::ipc::{read_frame, write_frame};
use fadec::runtime::{
    is_backend_down, HwBackend, IpcBackend, SupervisorOptions,
};
use fadec::tensor::{Tensor, TensorF};

const SEED: u64 = 7;

/// The worker executable cargo built alongside this test binary.
fn worker_exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_fadec"))
}

/// Supervisor options with both hang detectors disabled — fault-free
/// tests must never depend on debug-build timing.
fn detectors_off(seed: u64) -> SupervisorOptions {
    SupervisorOptions {
        seed,
        heartbeat_grace: Duration::ZERO,
        wait_deadline: Duration::ZERO,
        worker_exe: Some(worker_exe()),
        ..SupervisorOptions::for_seed(seed)
    }
}

fn fast_retry(attempts: usize) -> RetryPolicy {
    RetryPolicy {
        backoff: Duration::from_micros(50),
        ..RetryPolicy::with_attempts(attempts)
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("fadec_supervision_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn make_scenes(n_streams: usize, frames: usize, base_seed: u64) -> Vec<Scene> {
    (0..n_streams)
        .map(|s| {
            Scene::synthetic(&format!("sv-{s}"), frames, base_seed + s as u64)
        })
        .collect()
}

/// Fault-free single-stream reference on a clean in-process backend.
fn solo_run(scene: &Scene, n: usize) -> Vec<TensorF> {
    let mut coord =
        Coordinator::on_ref_backend(SEED, PipelineOptions::default()).unwrap();
    (0..n)
        .map(|i| {
            let img = scene.normalized_image(i);
            coord.step(&img, &scene.poses[i]).unwrap().depth
        })
        .collect()
}

fn assert_depths_eq(got: &[Vec<TensorF>], want: &[Vec<TensorF>], tag: &str) {
    assert_eq!(got.len(), want.len(), "{tag}: stream count");
    for (s, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.len(), w.len(), "{tag}: stream {s} frame count");
        for (i, (a, b)) in g.iter().zip(w).enumerate() {
            assert_eq!(
                a.data(),
                b.data(),
                "{tag}: stream {s} frame {i} diverged"
            );
        }
    }
}

/// Drive every stream through `frames` lockstep rounds on a router and
/// collect depths per stream.
fn route_all(
    router: &mut ShardRouter,
    scenes: &[Scene],
    frames: usize,
) -> Vec<Vec<TensorF>> {
    let streams: Vec<usize> =
        scenes.iter().map(|_| router.open_stream()).collect();
    let imgs: Vec<Vec<TensorF>> = (0..frames)
        .map(|i| scenes.iter().map(|sc| sc.normalized_image(i)).collect())
        .collect();
    let rounds: Vec<Vec<(usize, &TensorF, &Mat4)>> = (0..frames)
        .map(|i| {
            streams
                .iter()
                .map(|&s| (s, &imgs[i][s], &scenes[s].poses[i]))
                .collect()
        })
        .collect();
    let results = router.run_rounds_seq(&rounds, 2).unwrap();
    let mut depths: Vec<Vec<TensorF>> =
        scenes.iter().map(|_| Vec::new()).collect();
    for round in results {
        for (sid, out) in round {
            depths[sid].push(out.depth);
        }
    }
    depths
}

// --- tentpole: process isolation is invisible to the bits ------------------

#[test]
fn process_isolated_fleet_is_bit_exact_for_k1_and_k2() {
    let (n_streams, frames) = (2, 3);
    let scenes = make_scenes(n_streams, frames, 40);
    for k in [1usize, 2] {
        let ropts = ShardRouterOptions {
            auto_rebalance: false,
            ..Default::default()
        };
        let mut inproc = ShardRouter::on_ref_backends(
            k,
            SEED,
            PipelineOptions::default(),
            ropts,
        )
        .unwrap();
        let want = route_all(&mut inproc, &scenes, frames);
        let mut iso = ShardRouter::on_worker_processes(
            k,
            SEED,
            PipelineOptions::default(),
            ropts,
            detectors_off(SEED),
        )
        .unwrap();
        let got = route_all(&mut iso, &scenes, frames);
        assert_depths_eq(&got, &want, &format!("isolated k={k}"));
        // a fault-free run needs no supervision at all — and therefore
        // adds no supervision line to the report
        let sup = iso.supervisor_stats();
        assert_eq!(sup.restarts, 0, "k={k}");
        assert_eq!(sup.heartbeat_misses, 0, "k={k}");
        assert_eq!(sup.deadline_expiries, 0, "k={k}");
        assert_eq!(sup.failover_replays, 0, "k={k}");
        assert!(!iso.report().contains("supervision:"));
    }
}

// --- crash containment: SIGKILL mid-window ---------------------------------

#[test]
fn killed_worker_fails_over_through_checkpoints_bit_exactly() {
    let dir = tmp_dir("kill");
    let (n_streams, frames) = (4, 6);
    let scenes = make_scenes(n_streams, frames, 60);
    let solo: Vec<Vec<TensorF>> =
        scenes.iter().map(|sc| solo_run(sc, frames)).collect();

    // two worker processes; worker 0 will be killed with no restart
    // budget, so its shard dies for good and failover must carry it
    let mut opts0 = detectors_off(SEED);
    opts0.max_restarts = 0;
    let be0 = Arc::new(IpcBackend::connect(opts0).unwrap());
    let be1 = Arc::new(IpcBackend::connect(detectors_off(SEED)).unwrap());
    let qp0 = Arc::clone(be0.qp());
    let qp1 = Arc::clone(be1.qp());
    let mut router = ShardRouter::new(
        vec![
            (Arc::clone(&be0) as Arc<dyn HwBackend>, qp0),
            (Arc::clone(&be1) as Arc<dyn HwBackend>, qp1),
        ],
        PipelineOptions { retry: fast_retry(3), ..Default::default() },
        ShardRouterOptions {
            placement: Placement::RoundRobin,
            auto_rebalance: false,
            imbalance_threshold: 1.5,
        },
    )
    .unwrap();
    let store = SessionStore::open(
        &dir,
        8,
        be0.manifest(),
        router.engine(0).qp().as_ref(),
    )
    .unwrap();
    router.attach_session_store(store);

    let streams: Vec<usize> =
        (0..n_streams).map(|_| router.open_stream()).collect();
    let on_dead: Vec<usize> = streams
        .iter()
        .copied()
        .filter(|&s| router.shard_of(s) == Some(0))
        .collect();
    assert!(!on_dead.is_empty(), "round-robin placed streams on shard 0");

    let imgs: Vec<Vec<TensorF>> = (0..frames)
        .map(|i| scenes.iter().map(|sc| sc.normalized_image(i)).collect())
        .collect();
    let rounds = |lo: usize, hi: usize| -> Vec<Vec<(usize, &TensorF, &Mat4)>> {
        (lo..hi)
            .map(|i| {
                streams
                    .iter()
                    .map(|&s| (s, &imgs[i][s], &scenes[s].poses[i]))
                    .collect()
            })
            .collect()
    };
    let mut got: Vec<Vec<TensorF>> =
        (0..n_streams).map(|_| Vec::new()).collect();
    let take = |results: Vec<Vec<(usize, fadec::coordinator::FrameOutput)>>,
                    got: &mut Vec<Vec<TensorF>>| {
        for round in results {
            for (sid, out) in round {
                got[sid].push(out.depth);
            }
        }
    };

    // window 1: both workers healthy
    take(router.run_rounds(&rounds(0, 2), 2).unwrap(), &mut got);
    // SIGKILL worker 0; window 2 begins unaware — submissions to the
    // dead shard exhaust their retries against the spent restart
    // budget, then checkpoint failover ships its streams to shard 1
    // and replays the unfinished rounds there
    be0.kill_worker();
    take(router.run_rounds(&rounds(2, 4), 2).unwrap(), &mut got);
    for &s in &on_dead {
        assert_eq!(router.shard_of(s), Some(1), "victim {s} failed over");
    }
    // window 3: the surviving worker serves everything
    take(router.run_rounds(&rounds(4, 6), 2).unwrap(), &mut got);

    assert_depths_eq(&got, &solo, "kill failover");
    let rec = router.recovery_stats();
    assert_eq!(rec.shard_failovers, 1, "one worker died once");
    assert_eq!(
        rec.checkpoint_migrations,
        on_dead.len(),
        "every victim shipped through its checkpoint"
    );
    let sup = router.supervisor_stats();
    assert_eq!(sup.failover_replays, 1, "the death was replayed once");
    assert_eq!(sup.restarts, 0, "no budget, no restart");
    assert_eq!(sup.heartbeat_misses + sup.deadline_expiries, 0);
    assert!(router.report().contains("supervision:"));
    let _ = std::fs::remove_dir_all(&dir);
}

// --- hang detection: stalled serve loop trips the wait deadline ------------

#[test]
fn stalled_worker_trips_the_wait_deadline_and_restarts() {
    let (frames, cut) = (4, 2);
    let scenes = make_scenes(1, frames, 50);
    let solo = solo_run(&scenes[0], frames);

    // heartbeat detector off: the stalled worker keeps beating, so
    // only the per-wait deadline may fire — making the counts exact
    let opts = SupervisorOptions {
        heartbeat_grace: Duration::ZERO,
        wait_deadline: Duration::from_secs(2),
        max_restarts: 2,
        restart_backoff: Duration::from_millis(10),
        ..detectors_off(SEED)
    };
    let be = Arc::new(IpcBackend::connect(opts).unwrap());
    let qp = Arc::clone(be.qp());
    let mut server = StreamServer::new(
        Arc::clone(&be) as Arc<dyn HwBackend>,
        qp,
        // the pipeline's own per-wait deadline (round_timeout, 5 s)
        // stays longer than the supervisor's, so the supervisor kills
        // first and the retry replays against the restarted worker
        PipelineOptions { retry: fast_retry(3), ..Default::default() },
    )
    .unwrap();
    let s = server.open_stream();
    for (i, want) in solo.iter().enumerate().take(cut) {
        let img = scenes[0].normalized_image(i);
        let out = server.step_stream(s, &img, &scenes[0].poses[i]).unwrap();
        assert_eq!(out.depth.data(), want.data(), "prefix frame {i}");
    }
    // wedge the serve loop (heartbeats keep flowing); the next request
    // outlives the wait deadline, the supervisor kills the worker, the
    // dropped wait registers as a retryable fault, and the retry runs
    // against the supervised restart
    be.stall_worker().unwrap();
    for (i, want) in solo.iter().enumerate().skip(cut) {
        let img = scenes[0].normalized_image(i);
        let out = server.step_stream(s, &img, &scenes[0].poses[i]).unwrap();
        assert_eq!(out.depth.data(), want.data(), "continuation frame {i}");
    }
    let sup = server.supervisor_stats().unwrap();
    assert_eq!(sup.deadline_expiries, 1, "exactly one hang detected");
    assert_eq!(sup.restarts, 1, "exactly one supervised restart");
    assert_eq!(sup.heartbeat_misses, 0, "heartbeat detector was off");
    assert!(sup.downtime_seconds > 0.0);
    assert!(server.recovery_stats().wait_faults >= 1);
    assert!(server.report().contains("supervision:"));
}

// --- hang detection: frozen process misses heartbeats ----------------------

#[test]
fn frozen_worker_misses_heartbeats_and_restarts() {
    let (frames, cut) = (4, 2);
    let scenes = make_scenes(1, frames, 55);
    let solo = solo_run(&scenes[0], frames);

    // wait-deadline detector off: only heartbeat staleness may fire
    let opts = SupervisorOptions {
        heartbeat_interval: Duration::from_millis(25),
        heartbeat_grace: Duration::from_millis(500),
        wait_deadline: Duration::ZERO,
        max_restarts: 2,
        restart_backoff: Duration::from_millis(10),
        ..detectors_off(SEED)
    };
    let be = Arc::new(IpcBackend::connect(opts).unwrap());
    let qp = Arc::clone(be.qp());
    let mut server = StreamServer::new(
        Arc::clone(&be) as Arc<dyn HwBackend>,
        qp,
        PipelineOptions::default(),
    )
    .unwrap();
    let s = server.open_stream();
    for (i, want) in solo.iter().enumerate().take(cut) {
        let img = scenes[0].normalized_image(i);
        let out = server.step_stream(s, &img, &scenes[0].poses[i]).unwrap();
        assert_eq!(out.depth.data(), want.data(), "prefix frame {i}");
    }
    // freeze the whole process (even its heartbeat thread parks); the
    // monitor must notice the stale beat and kill it between rounds —
    // no request is in flight, so no retry policy is needed at all
    be.freeze_worker().unwrap();
    let t0 = Instant::now();
    while be.supervisor_stats().unwrap().heartbeat_misses == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(20),
            "frozen worker was never detected"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    // the next submission finds the worker down and restarts it
    for (i, want) in solo.iter().enumerate().skip(cut) {
        let img = scenes[0].normalized_image(i);
        let out = server.step_stream(s, &img, &scenes[0].poses[i]).unwrap();
        assert_eq!(out.depth.data(), want.data(), "continuation frame {i}");
    }
    let sup = server.supervisor_stats().unwrap();
    assert_eq!(sup.heartbeat_misses, 1, "exactly one frozen worker");
    assert_eq!(sup.restarts, 1, "exactly one supervised restart");
    assert_eq!(sup.deadline_expiries, 0, "deadline detector was off");
}

// --- restart budget exhaustion surfaces as a typed error -------------------

#[test]
fn restart_budget_exhaustion_is_a_typed_fast_error() {
    let scenes = make_scenes(1, 2, 65);
    let solo = solo_run(&scenes[0], 1);
    let mut opts = detectors_off(SEED);
    opts.max_restarts = 0;
    let be = Arc::new(IpcBackend::connect(opts).unwrap());
    let qp = Arc::clone(be.qp());
    let mut server = StreamServer::new(
        Arc::clone(&be) as Arc<dyn HwBackend>,
        qp,
        PipelineOptions::default(),
    )
    .unwrap();
    let s = server.open_stream();
    let img = scenes[0].normalized_image(0);
    let out = server.step_stream(s, &img, &scenes[0].poses[0]).unwrap();
    assert_eq!(out.depth.data(), solo[0].data());
    be.kill_worker();
    let img = scenes[0].normalized_image(1);
    let err = server
        .step_stream(s, &img, &scenes[0].poses[1])
        .expect_err("dead worker with no restart budget must error");
    assert!(is_backend_down(&err), "typed BackendDown in: {err:#}");
    assert!(format!("{err:#}").contains("restart budget"), "{err:#}");
    // the failure must not wedge the caller: further calls fail fast
    // (no detector sleeps, no hung waits) with the same typed error
    let t0 = Instant::now();
    let err = server
        .step_stream(s, &img, &scenes[0].poses[1])
        .expect_err("still down");
    assert!(is_backend_down(&err));
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "a downed backend must fail fast, not hang"
    );
}

// --- wire protocol: torn and hostile streams are rejected ------------------

#[test]
fn frame_codec_rejects_torn_and_hostile_streams() {
    // a representative frame with a string-ish and a numeric entry
    let mut f = TlvFile::default();
    let name: Vec<i8> = b"run_batch".iter().map(|&b| b as i8).collect();
    f.insert(
        "op",
        TlvEntry {
            exp: 0,
            payload: TlvPayload::I8(Tensor::from_vec(&[name.len()], name)),
        },
    )
    .unwrap();
    f.insert(
        "width",
        TlvEntry {
            exp: 0,
            payload: TlvPayload::I32(Tensor::from_vec(&[2], vec![7, -7])),
        },
    )
    .unwrap();
    let mut buf = Vec::new();
    write_frame(&mut buf, &f).unwrap();

    // clean EOF only at a frame boundary
    assert!(read_frame(&mut Cursor::new(Vec::new())).unwrap().is_none());
    let back = read_frame(&mut Cursor::new(buf.clone())).unwrap().unwrap();
    assert!(back.entries.contains_key("op"));
    // two frames back to back parse in order, then EOF cleanly
    let mut two = buf.clone();
    two.extend_from_slice(&buf);
    let mut cur = Cursor::new(two);
    assert!(read_frame(&mut cur).unwrap().is_some());
    assert!(read_frame(&mut cur).unwrap().is_some());
    assert!(read_frame(&mut cur).unwrap().is_none());

    // every strict prefix is an error — truncation never reads as a
    // clean shutdown past offset zero
    for cut in 1..buf.len() {
        assert!(
            read_frame(&mut Cursor::new(buf[..cut].to_vec())).is_err(),
            "prefix of {cut}/{} bytes must not parse",
            buf.len()
        );
    }
    // a hostile length field is rejected before any allocation
    let mut hostile = Vec::new();
    hostile.extend_from_slice(&u32::MAX.to_le_bytes());
    hostile.extend_from_slice(&[0u8; 16]);
    let err = read_frame(&mut Cursor::new(hostile)).unwrap_err();
    assert!(format!("{err:#}").contains("bound"), "{err:#}");

    // seeded fuzz: arbitrary byte soup must error or end cleanly —
    // never panic, never loop — and single-byte corruptions of a valid
    // frame must never be silently accepted as a *different* frame
    let mut state = 0x2545_F491_4F6C_DD1Du64;
    let mut rng = move || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        state
    };
    for _ in 0..256 {
        let len = (rng() % 96) as usize;
        let junk: Vec<u8> = (0..len).map(|_| rng() as u8).collect();
        let mut cur = Cursor::new(junk);
        // drain the cursor: each read either errors (lost sync) or
        // yields a frame; a finite buffer must terminate either way
        loop {
            match read_frame(&mut cur) {
                Ok(Some(_)) => continue,
                Ok(None) | Err(_) => break,
            }
        }
    }
    for i in 0..buf.len() {
        let mut bent = buf.clone();
        bent[i] ^= 1 << (rng() % 8) as u32;
        let mut cur = Cursor::new(bent);
        // flipping a bit may legally still parse (e.g. inside payload
        // bytes) — what it must never do is panic or hang
        let _ = read_frame(&mut cur);
    }
}
