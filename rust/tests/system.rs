//! System-level integration tests. The coordinator/server invariants run
//! on the artifact-free `RefBackend` (synthetic manifest + parameters +
//! scenes), so they pass from a clean checkout; the tests over the built
//! artifacts are `#[ignore]`d and run with `-- --ignored` after
//! `make artifacts`.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use fadec::config;
use fadec::coordinator::{Coordinator, PipelineOptions};
use fadec::data::dataset::{Dataset, Scene, EVAL_SCENES};
use fadec::data::manifest::Manifest;
use fadec::model::{specs, FloatParams, QuantParams};
use fadec::util::Rng;

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
#[ignore = "requires `make artifacts`"]
fn dataset_all_scenes_load_and_are_sane() {
    let ds = Dataset::open(&artifacts().join("dataset")).unwrap();
    for name in EVAL_SCENES {
        let s = ds.load_scene(name).unwrap();
        assert!(s.len() >= 8, "{name} too short");
        for i in 0..s.len() {
            let d = &s.depths[i];
            assert!(d.iter().all(|&v| (config::MIN_DEPTH..=config::MAX_DEPTH)
                .contains(&v)));
            // rigid pose
            let p = &s.poses[i];
            for r in 0..3 {
                let mut norm = 0.0;
                for c in 0..3 {
                    norm += p.at(r, c) * p.at(r, c);
                }
                assert!((norm - 1.0).abs() < 1e-4, "{name} frame {i} row {r}");
            }
        }
        // camera actually moves between first and last frame
        let d = fadec::poses::pose_distance(&s.poses[0], &s.poses[s.len() - 1]);
        assert!(d > 0.05, "{name}: static camera ({d})");
    }
}

#[test]
#[ignore = "requires `make artifacts`"]
fn manifest_matches_specs_and_weights() {
    let art = artifacts();
    let manifest = Manifest::load(&art.join("manifest.txt")).unwrap();
    let fp = FloatParams::load(&art.join("weights.bin")).unwrap();
    let qp = QuantParams::load(&art.join("qparams.bin"), &manifest).unwrap();
    qp.validate().unwrap();

    // every conv spec has float + quant weights of matching shapes
    for s in specs::all_conv_specs() {
        let f = fp.conv(&s.name);
        let q = qp.conv(&s.name);
        let expect: Vec<usize> = if s.dw {
            vec![s.cout, 1, s.k, s.k]
        } else {
            vec![s.cout, s.cin, s.k, s.k]
        };
        assert_eq!(f.w.shape(), expect.as_slice(), "{}", s.name);
        assert_eq!(q.w.shape(), expect.as_slice(), "{}", s.name);
        assert_eq!(f.b.len(), s.cout);
        assert_eq!(q.b.len(), s.cout);
        // quantized weights fit the 8-bit range by construction
        assert!(q.w.data().iter().all(|&v| (-127..=127).contains(&v)),
                "{} weights out of int8 range", s.name);
    }
    // every LN site has parameters
    for n in specs::ln_names() {
        assert_eq!(fp.ln(&n).gamma.len(), specs::ln_channels(&n));
        assert_eq!(qp.ln(&n).gamma.len(), specs::ln_channels(&n));
    }
    // the manifest's 19 segments with consistent I/O shapes
    assert_eq!(manifest.segments.len(), 19);
    for seg in &manifest.segments {
        assert!(!seg.inputs.is_empty() && !seg.outputs.is_empty());
        for t in seg.inputs.iter().chain(&seg.outputs) {
            assert_eq!(t.shape.len(), 4, "{}:{}", seg.name, t.name);
            assert_eq!(t.shape[0], 1);
        }
    }
    // training actually ran and converged below the init-loss regime
    assert!(manifest.train_steps >= 100);
    assert!(manifest.train_final_loss < 0.1,
            "final loss {}", manifest.train_final_loss);
}

#[test]
fn coordinator_invariants_under_randomized_stream() {
    // Property test on the artifact-free RefBackend: whatever the (valid)
    // pose sequence, the coordinator must produce depths within range,
    // keep the KB within capacity, and never deadlock. Randomized
    // frame/pose pairings over a synthetic scene stress the KB + the
    // hidden-state correction.
    let mut coord =
        Coordinator::on_ref_backend(0xFADEC, PipelineOptions::default()).unwrap();
    assert_eq!(coord.backend().kind(), "ref");
    let scene = Scene::synthetic("invariants", 12, 17);

    let mut rng = Rng::new(0xFADEC);
    for trial in 0..3 {
        coord.reset_stream();
        assert_eq!(coord.frames_done(), 0);
        for i in 0..5 {
            // random frame / pose pairing stresses the KB + correction
            let fi = rng.below(scene.len() as u64) as usize;
            let img = scene.normalized_image(fi);
            let pose = scene.poses[rng.below(scene.len() as u64) as usize];
            let out = coord.step(&img, &pose).unwrap();
            assert!(
                out.depth.data().iter().all(|&d| (config::MIN_DEPTH - 1e-3
                    ..=config::MAX_DEPTH + 1e-3)
                    .contains(&d)),
                "trial {trial} frame {i}: depth out of range"
            );
            assert!(coord.session().kb.len() <= config::KB_CAPACITY);
            // profile sanity: stages within the frame, HW lane non-empty
            let p = &out.profile;
            assert!(p.hw_busy() > 0.0);
            for s in &p.stages {
                assert!(s.end_s >= s.start_s);
                assert!(s.end_s <= p.total_s + 1e-6);
            }
        }
        assert_eq!(coord.frames_done(), 5);
    }
}

#[test]
fn overlap_ablation_is_bit_identical_on_ref_backend() {
    // Task-level parallelization must not change results, only timing —
    // provable without artifacts on the RefBackend.
    let mk = |overlap: bool| {
        Coordinator::on_ref_backend(
            42,
            PipelineOptions { overlap, sw_threads: 2, ..Default::default() },
        )
        .unwrap()
    };
    let mut with = mk(true);
    let mut without = mk(false);
    let scene = Scene::synthetic("ablation", 4, 5);
    for fi in 0..scene.len() {
        let img = scene.normalized_image(fi);
        let a = with.step(&img, &scene.poses[fi]).unwrap();
        let b = without.step(&img, &scene.poses[fi]).unwrap();
        assert_eq!(a.depth.data(), b.depth.data(), "frame {fi}");
    }
}

#[test]
fn pjrt_runtime_reports_missing_artifacts_cleanly() {
    // From a clean checkout the PJRT path must fail with a diagnosable
    // error (missing artifacts or stubbed xla runtime), never a panic.
    let manifest = Manifest::synthetic();
    let qp = Arc::new(QuantParams::synthetic(&manifest, 1));
    let err = Coordinator::new(
        &artifacts(),
        &manifest,
        qp,
        PipelineOptions::default(),
    )
    .err()
    .expect("clean checkout has no artifacts");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("artifact") || msg.contains("PJRT"),
        "unexpected error: {msg}"
    );
}

#[test]
fn extern_overhead_definition_holds() {
    // overhead = (HW wait) - (SW time) must be non-negative and small
    // relative to the SW time for synchronous ops on an idle pool.
    let link = fadec::coordinator::ExternLink::new(2);
    assert_eq!(link.workers(), 2);
    for _ in 0..50 {
        link.call("spin", || {
            std::hint::black_box((0..20_000).fold(0u64, |a, b| a ^ b));
        });
    }
    let stats = link.take_stats();
    assert_eq!(stats.records.len(), 50);
    for r in &stats.records {
        assert!(r.overhead_seconds >= 0.0);
        assert!(r.total_seconds >= r.sw_seconds);
    }
}

#[test]
fn reports_generate() {
    let t1 = fadec::report::tables::table_i();
    assert!(t1.contains("MATCHES"));
    let f2 = fadec::report::tables::fig_2();
    assert!(f2.contains("CVE+CVD share"));
    let r = fadec::report::tables::resources_report();
    assert!(r.contains("BRAM"));
    let m = fadec::hwsim::TableIIModel::compute();
    assert!(m.speedup > 10.0);
}
