//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment for this repository has no crates.io access, so
//! the exact API subset the `fadec` crate uses is reimplemented here:
//! `Error`, `Result`, the `anyhow!` / `bail!` / `ensure!` macros, and the
//! `Context` extension trait for `Result` and `Option`. Contexts are kept
//! as a simple message chain; `{:#}` prints the full chain inline and
//! `{:?}` prints it as anyhow's familiar "Caused by:" block.

use std::convert::Infallible;
use std::error::Error as StdError;
use std::fmt;

/// A message-chain error: `msg` is the outermost context, `source` the
/// next layer down (ending at the root cause).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error in one more layer of context.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// Messages outermost-first (context chain ending at the root cause).
    pub fn chain(&self) -> Vec<&str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        *self.chain().last().expect("non-empty chain")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain inline, outermost first
            write!(f, "{}", self.chain().join(": "))
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if let Some(mut cur) = self.source.as_deref() {
            write!(f, "\n\nCaused by:")?;
            loop {
                write!(f, "\n    {}", cur.msg)?;
                match cur.source.as_deref() {
                    Some(next) => cur = next,
                    None => break,
                }
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what makes the blanket `From` below coherent (same trick as anyhow).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // flatten the std error source chain into the message chain
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut it = msgs.into_iter().rev();
        let mut err = Error::msg(it.next().expect("at least one message"));
        for m in it {
            err = Error { msg: m, source: Some(Box::new(err)) };
        }
        err
    }
}

/// `anyhow::Result<T>` — `Result` with `Error` as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

/// Anything convertible into [`Error`] (std errors and `Error` itself);
/// the dispatch trait behind [`Context`]. Not intended for direct use.
#[doc(hidden)]
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

impl<E: StdError + Send + Sync + 'static> IntoError for E {
    fn into_error(self) -> Error {
        Error::from(self)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` (any std error type, or `Error` itself) and `Option`.
pub trait Context<T, E>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: IntoError> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let v: i32 = s.parse().context("not an integer")?;
        ensure!(v >= 0, "negative value {v}");
        Ok(v)
    }

    #[test]
    fn context_chain_formats() {
        let e = parse("x").unwrap_err();
        assert_eq!(format!("{e}"), "not an integer");
        let full = format!("{e:#}");
        assert!(full.starts_with("not an integer: "), "{full}");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn ensure_and_bail() {
        assert_eq!(parse("7").unwrap(), 7);
        let e = parse("-3").unwrap_err();
        assert_eq!(format!("{e}"), "negative value -3");
        fn fail() -> Result<()> {
            bail!("boom {}", 42);
        }
        assert_eq!(format!("{}", fail().unwrap_err()), "boom 42");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
        let e = (None as Option<i32>)
            .with_context(|| format!("missing {}", "thing"))
            .unwrap_err();
        assert_eq!(format!("{e}"), "missing thing");
    }

    #[test]
    fn from_std_error_keeps_source_chain() {
        let io = std::io::Error::other("root");
        let e = Error::from(io).context("outer");
        assert_eq!(e.root_cause(), "root");
        assert_eq!(format!("{e:#}"), "outer: root");
    }
}
