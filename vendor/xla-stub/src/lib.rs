//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The real crate links `libxla_extension`, which this build environment
//! does not ship. The stub keeps the exact API surface `fadec::runtime`
//! uses so the crate compiles everywhere; every entry point returns a
//! "PJRT unavailable" error at runtime. `PjRtClient::cpu()` is the single
//! gate: it always fails here, so no executable, buffer or literal can
//! ever be constructed, and the methods past that gate are unreachable.
//!
//! Swapping this path dependency for the real xla-rs crate restores the
//! hardware-artifact backend without touching `fadec` source.

use std::fmt;

/// Stub error ("PJRT unavailable: ...").
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "PJRT unavailable: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} requires the xla_extension runtime; this build uses the \
         offline stub (vendor/xla-stub). Use the RefBackend instead, or \
         link the real xla-rs crate."
    ))
}

/// Element types the runtime constructs literals with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    S16,
}

/// Stub PJRT client — `cpu()` always fails in the offline build.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(unavailable("Literal::create_from_shape_and_untyped_data"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        let msg = err.to_string();
        assert!(msg.contains("PJRT unavailable"), "{msg}");
        assert!(msg.contains("RefBackend"), "{msg}");
    }
}
